//! `cargo bench --bench microbench` — hot-path micro-benchmarks of the L3
//! coordinator (criterion is unreachable offline; this is a from-scratch
//! timing harness with warmup-discard + median/relative-stddev reporting).
//! Feeds EXPERIMENTS.md §Perf and the machine-readable perf trajectory.
//!
//! Paths measured, each as a (baseline, incremental) pair where a pre-PR
//! path exists:
//!   * scheduler decision per iteration at pool sizes 100/1000/5000 —
//!     clone-trial `OracleScheduler` vs. apply/undo `Scheduler`
//!   * router digest sync at replica counts 1/4/16 over a 5000-key cache —
//!     full prefix-summary resync vs. delta (churn-only) protocol
//!   * fleet stepping at 4/16/64 replicas — serial replica advance vs. the
//!     scoped worker pool at 2/4/8 threads (macro pairs: fixed iteration
//!     counts, meaningful even under `--quick`)
//!   * engine step allocation count — a counting global allocator proves
//!     the steady-state step loop is allocation-free (release builds)
//!   * obs-step pair — the engine step loop with tracing disabled (the
//!     `Option<TraceRing>` branch is a no-op) vs enabled (every step
//!     records an iteration span into the ring); fixed iteration counts,
//!     so `--gate-obs` sees real timings even under `--quick`
//!   * faults-step pair — the engine step loop with no fault schedule
//!     installed (the `Option<ReplicaFaults>` hook folds to a skipped
//!     branch) vs a non-empty schedule whose events never fire; fixed
//!     iteration counts, so `--gate-faults` sees real timings even under
//!     `--quick`
//!   * slo-tick pair — a fleet quantum (replica advance + coordinator
//!     finish) with no SLO guard configured vs the guard armed but idle
//!     (target 0.0: the controller folds fleet histograms and runs the
//!     control law every quantum yet never actuates); fixed iteration
//!     counts, so `--gate-slo` sees real timings even under `--quick`
//!   * journal-step pair — the serve pump loop with the durable-session
//!     journal disarmed (one `Option` check) vs armed but idle (no keyed
//!     submits, so every pump pays exactly the `is_empty()` fast path);
//!     fixed iteration counts, so `--gate-durable` sees real timings even
//!     under `--quick`
//!   * health-tick pair — a fleet quantum with no gray-failure monitor vs
//!     the monitor armed on a healthy fleet (every quantum folds each
//!     replica's drift window; no transition ever fires); fixed iteration
//!     counts, so `--gate-durable` sees real timings even under `--quick`
//!   * KV manager hot paths at 1k/16k/64k blocks — pre-PR `OracleKvManager`
//!     (global BTreeSet free table, scan-per-call availability) vs. the
//!     bucketed victim index: allocate+release cycle, `availability()`,
//!     register/unregister requeue storms, eviction churn (fixed iteration
//!     counts, so `--gate-kv` sees real timings even under `--quick`)
//!   * radix index (arena): insert/remove churn and `best_cached`
//!   * KV prefix lookup and eviction preview (no pre-PR counterpart)
//!   * content keys: direct chain hash vs. interned accessor
//!   * estimator: `batch_time` re-scan vs. `batch_time_inc` aggregates
//!   * end-to-end sim iterations/second
//!   * PJRT step latency per bucket (if artifacts are built)
//!
//! Flags (after `--`):
//!   `--bench-json <path>`        write the machine-readable report
//!                                (default name: BENCH_PR10.json) and
//!                                self-validate it by re-parsing
//!   `--quick`                    tiny iteration counts (CI smoke: proves
//!                                the harness runs headless; micro timings
//!                                are meaningless, fleet + kv pairs stay
//!                                real)
//!   `--gate-fleet`               fail unless the parallel fleet advance at
//!                                16 replicas / 4 threads is at least as
//!                                fast as serial (the CI perf gate)
//!   `--gate-kv`                  fail unless every KV pair is at least
//!                                1.0x vs. the oracle baseline and the
//!                                steady-state engine step allocation
//!                                count is 0 (release builds)
//!   `--gate-obs`                 fail unless the traced engine step stays
//!                                within the noise band of the untraced
//!                                one and the steady-state step loop stays
//!                                allocation-free with tracing off
//!   `--gate-faults`              fail unless the engine step with a fault
//!                                schedule installed (but never firing)
//!                                stays within the noise band of the
//!                                hook-free step, and the steady-state
//!                                step loop stays allocation-free with
//!                                injection disabled
//!   `--gate-slo`                 fail unless the fleet quantum with the
//!                                SLO guard armed-but-idle stays within the
//!                                noise band of the guardless quantum, and
//!                                the steady-state engine step stays
//!                                allocation-free with the controller off
//!   `--gate-durable`             fail unless the armed-idle journal pump
//!                                and the armed-healthy health tick each
//!                                stay within the noise band of their
//!                                disarmed twins, and the steady-state
//!                                engine step stays allocation-free with
//!                                both disarmed
//!   `--write-experiments <path>` rewrite the `<!-- perf:begin/end -->`
//!                                block of EXPERIMENTS.md with the
//!                                before/after table

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use echo::cluster::{
    offline_jobs, ClusterConfig, ClusterSim, HealthConfig, LoadDigest, OnlineJob, PrefixSummary,
    Router,
};
use echo::config::{SchedulerKind, SystemConfig};
use echo::core::{PromptSpec, Request, RequestStore, TaskClass};
use echo::engine::{sim::SimBackend, Engine};
use echo::estimator::{BatchShape, PrefillItem, TimeModel, TrialShape};
use echo::kvcache::{Availability, EvictionPolicy, KvManager, OracleKvManager};
use echo::scheduler::{OfflinePool, OracleScheduler, RadixIndex, Scheduler};
use echo::serve::{EngineServe, JournalConfig, NullSink, Serve, SubmitSpec};
use echo::slo::SloGuardConfig;
use echo::utils::json::Json;
use echo::utils::rng::Rng;
use echo::workload::{synthesize, DatasetSpec};

// ---- counting allocator ---------------------------------------------------

/// Counting wrapper around the system allocator: every alloc/realloc bumps
/// a relaxed counter, so the bench can measure allocations per engine step
/// and prove the steady-state loop is allocation-free (release builds;
/// debug builds allocate in `debug_assert!` scaffolding by design).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// ---- harness -------------------------------------------------------------

#[derive(Clone, Debug)]
struct BenchEntry {
    /// Display name.
    name: String,
    /// Category the perf gate keys on: "scheduler-decision", "digest-sync",
    /// "radix", "kv-alloc-release", ...
    path: String,
    /// "baseline" (pre-PR code path) or "incremental".
    variant: String,
    /// Problem size (pool size, replica count, ... 0 if not applicable).
    size: usize,
    median_ns: f64,
    rel_stddev: f64,
    iters: usize,
    runs: usize,
}

struct Harness {
    entries: Vec<BenchEntry>,
    /// Scale factor for iteration counts (quick mode shrinks to ~nothing).
    scale: f64,
}

impl Harness {
    fn new(quick: bool) -> Self {
        Harness {
            entries: Vec::new(),
            scale: if quick { 0.01 } else { 1.0 },
        }
    }

    /// Median wall-time per op over `runs` timed batches of `iters` ops,
    /// after one warmup batch whose samples are discarded (cold caches,
    /// lazy allocations, and branch-predictor warmup never pollute the
    /// recorded runs). Also reports relative stddev across the runs so
    /// noisy numbers are visibly noisy.
    fn bench<F: FnMut()>(
        &mut self,
        name: &str,
        path: &str,
        variant: &str,
        size: usize,
        iters: usize,
        mut f: F,
    ) -> f64 {
        let iters = ((iters as f64 * self.scale) as usize).max(2);
        let runs = 7usize;
        // Warmup batch: run and discard.
        for _ in 0..iters.min(200) {
            f();
        }
        let mut samples: Vec<f64> = (0..runs)
            .map(|_| {
                let t0 = Instant::now();
                for _ in 0..iters {
                    f();
                }
                t0.elapsed().as_secs_f64() / iters as f64
            })
            .collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        let rel_sd = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };
        let unit = if med < 1e-6 {
            format!("{:.1} ns", med * 1e9)
        } else if med < 1e-3 {
            format!("{:.2} us", med * 1e6)
        } else {
            format!("{:.3} ms", med * 1e3)
        };
        println!("{name:<62} {unit:>12}/op  (±{:>4.1}%)", rel_sd * 100.0);
        self.entries.push(BenchEntry {
            name: name.to_string(),
            path: path.to_string(),
            variant: variant.to_string(),
            size,
            median_ns: med * 1e9,
            rel_stddev: rel_sd,
            iters,
            runs,
        });
        med
    }

    /// Like [`Harness::bench`], but the iteration count is **not**
    /// `--quick`-scaled: gated pairs (kv, fleet) must produce real timings
    /// in the CI smoke run.
    fn bench_fixed<F: FnMut()>(
        &mut self,
        name: &str,
        path: &str,
        variant: &str,
        size: usize,
        iters: usize,
        f: F,
    ) -> f64 {
        let saved = self.scale;
        self.scale = 1.0;
        let med = self.bench(name, path, variant, size, iters, f);
        self.scale = saved;
        med
    }

    fn median_of(&self, path: &str, variant: &str, size: usize) -> Option<f64> {
        self.entries
            .iter()
            .find(|e| e.path == path && e.variant == variant && e.size == size)
            .map(|e| e.median_ns)
    }

    /// baseline / incremental speedup for one (path, size) pair.
    fn speedup(&self, path: &str, size: usize) -> Option<f64> {
        let base = self.median_of(path, "baseline", size)?;
        let inc = self.median_of(path, "incremental", size)?;
        if inc > 0.0 {
            Some(base / inc)
        } else {
            None
        }
    }

    fn to_json(&self, quick: bool, alloc: &AllocReport) -> Json {
        let rows: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                Json::obj()
                    .set("name", e.name.as_str())
                    .set("path", e.path.as_str())
                    .set("variant", e.variant.as_str())
                    .set("size", e.size)
                    .set("median_ns", e.median_ns)
                    .set("rel_stddev", e.rel_stddev)
                    .set("iters", e.iters)
                    .set("runs", e.runs)
            })
            .collect();
        let mut speedups = Json::obj();
        for (path, size) in [
            ("scheduler-decision", 100usize),
            ("scheduler-decision", 1000),
            ("scheduler-decision", 5000),
            ("digest-sync", 1),
            ("digest-sync", 4),
            ("digest-sync", 16),
        ] {
            if let Some(s) = self.speedup(path, size) {
                speedups = speedups.set(&format!("{path}@{size}"), s);
            }
        }
        for &replicas in &[4usize, 16, 64] {
            for &threads in &[2usize, 4, 8] {
                if let Some(s) = fleet_speedup(self, replicas, threads) {
                    speedups = speedups.set(&format!("fleet-step@{replicas}x{threads}"), s);
                }
            }
        }
        for path in KV_GATE_PATHS {
            for &size in &KV_SIZES {
                if let Some(s) = self.speedup(path, size) {
                    speedups = speedups.set(&format!("{path}@{size}"), s);
                }
            }
        }
        // Measured but ungated: the mid-bucket insert worst case.
        for &size in &KV_SIZES {
            if let Some(s) = self.speedup("kv-requeue-scatter", size) {
                speedups = speedups.set(&format!("kv-requeue-scatter@{size}"), s);
            }
        }
        if let Some(s) = self.speedup("obs-step", 8) {
            speedups = speedups.set("obs-step@8", s);
        }
        if let Some(s) = self.speedup("faults-step", 8) {
            speedups = speedups.set("faults-step@8", s);
        }
        if let Some(s) = self.speedup("slo-tick", 4) {
            speedups = speedups.set("slo-tick@4", s);
        }
        if let Some(s) = self.speedup("journal-step", 8) {
            speedups = speedups.set("journal-step@8", s);
        }
        if let Some(s) = self.speedup("health-tick", 4) {
            speedups = speedups.set("health-tick@4", s);
        }
        // Gate-coverage manifest (echo-lint G1): record which paths CI
        // asserts on and why the rest are tracked-only, so the report is
        // self-describing.
        let gated: Vec<Json> = GATED_PAIRS.iter().map(|&p| Json::from(p)).collect();
        let ungated: Vec<Json> = UNGATED_PAIRS
            .iter()
            .map(|&(p, why)| Json::obj().set("path", p).set("reason", why))
            .collect();
        Json::obj()
            .set("bench", "BENCH_PR10")
            .set(
                "note",
                "baseline = pre-PR code paths (clone-trial scheduler, full \
                 digest resync, serial fleet advance, BTreeSet KV manager) \
                 recorded by the same harness run",
            )
            .set("quick_mode", quick)
            .set("engine_step_allocs_steady", alloc.steady)
            .set("engine_step_allocs_mean", alloc.mean)
            .set("entries", Json::Arr(rows))
            .set("speedups", speedups)
            .set("gated_pairs", Json::Arr(gated))
            .set("ungated_pairs", Json::Arr(ungated))
    }
}

/// serial (`t1`) / parallel (`t<threads>`) speedup of the fleet advance at
/// one replica count.
fn fleet_speedup(h: &Harness, replicas: usize, threads: usize) -> Option<f64> {
    let base = h.median_of("fleet-step", "t1", replicas)?;
    let par = h.median_of("fleet-step", &format!("t{threads}"), replicas)?;
    if par > 0.0 {
        Some(base / par)
    } else {
        None
    }
}

// ---- scheduler decision: oracle vs delta ---------------------------------

enum SchedImpl {
    Delta(Scheduler),
    Oracle(OracleScheduler),
}

impl SchedImpl {
    fn schedule(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        queue: &mut VecDeque<u64>,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
    ) -> usize {
        match self {
            SchedImpl::Delta(s) => s.schedule(now, store, queue, pool, kv).plan.items.len(),
            SchedImpl::Oracle(s) => s.schedule(now, store, queue, pool, kv).plan.items.len(),
        }
    }
}

fn bench_scheduler_decision(h: &mut Harness, pool_size: usize, variant: &str) {
    let mut cfg = SystemConfig::a100_llama8b();
    cfg.scheduler.kind = SchedulerKind::Echo;
    let block_size = cfg.cache.block_size;
    let mut sched = match variant {
        "incremental" => SchedImpl::Delta(Scheduler::new(
            cfg.scheduler.clone(),
            cfg.slo,
            TimeModel::new(cfg.time_model),
            block_size,
        )),
        _ => SchedImpl::Oracle(OracleScheduler::new(
            cfg.scheduler.clone(),
            cfg.slo,
            TimeModel::new(cfg.time_model),
            block_size,
        )),
    };
    let mut store = RequestStore::new();
    let mut queue = VecDeque::new();
    let mut pool = OfflinePool::default_buckets();
    // Tiny memory: admissions fail fast, so the steady-state decision cost
    // (partition + candidate search) dominates.
    let mut kv = KvManager::new(256, block_size, EvictionPolicy::TaskAware);
    let mut rng = Rng::new(1);
    let spec = DatasetSpec::loogle_qa_short();
    let batch = synthesize(&spec, pool_size, TaskClass::Offline, 0.0, &mut store, &mut rng);
    for &id in &batch.ids {
        let keys = store.get(id).content_key_path(block_size).to_vec();
        kv.register_future(&keys);
        pool.add(id, store.get(id).prompt.total_len, keys);
    }
    // One running online decode so the SLO path is active.
    let online = store.fresh_id();
    let mut r = Request::new(online, TaskClass::Online, 0.0, PromptSpec::sim(100, None), 64);
    r.state = echo::core::ReqState::Running;
    r.phase = echo::core::Phase::Decode;
    r.computed = 100;
    r.generated = 1;
    r.token_times.push(0.0);
    store.insert(r);
    kv.allocate(online, TaskClass::Online, &[], 7, 0.0).unwrap();
    if let SchedImpl::Delta(ref mut s) = sched {
        s.adopt_running(online); // seeded Running outside the scheduler
    }
    let mut now = 0.0;
    h.bench(
        &format!("scheduler decision [{variant}] (Echo, pool={pool_size})"),
        "scheduler-decision",
        variant,
        pool_size,
        200,
        || {
            now += 0.01;
            let n = sched.schedule(now, &mut store, &mut queue, &mut pool, &mut kv);
            std::hint::black_box(n);
        },
    );
}

// ---- digest sync: full resync vs delta protocol --------------------------

/// One replica's cache, pre-warmed with `warm` distinct keys, plus an epoch
/// counter for generating churn.
struct SyncReplica {
    kv: KvManager,
    replica: usize,
    epoch: u64,
}

impl SyncReplica {
    fn new(replica: usize, warm: usize, delta: bool) -> Self {
        let mut kv = KvManager::new(warm, 16, EvictionPolicy::TaskAware);
        if delta {
            kv.enable_key_churn();
        }
        // Warm the cache to capacity in slabs.
        let mut id = 0u64;
        let mut key = 0u128;
        let slab = 250usize.min(warm);
        let mut left = warm;
        while left > 0 {
            let n = slab.min(left);
            id += 1;
            let keys: Vec<u128> = (0..n)
                .map(|_| {
                    key += 1;
                    ((replica as u128) << 96) | key
                })
                .collect();
            kv.allocate(id, TaskClass::Offline, &keys, n, id as f64).unwrap();
            kv.release(id, true);
            left -= n;
        }
        let _ = kv.take_key_churn(); // deltas start from the warm state
        SyncReplica { kv, replica, epoch: 0 }
    }

    /// Cache 8 fresh keys (evicting 8 old ones): the per-quantum churn.
    fn churn(&mut self) {
        self.epoch += 1;
        let id = 1_000_000 + self.epoch;
        let epoch_tag = (1u128 << 90) | ((self.epoch as u128) << 8);
        let keys: Vec<u128> = (0..8)
            .map(|i| ((self.replica as u128) << 96) | epoch_tag | i)
            .collect();
        self.kv
            .allocate(id, TaskClass::Offline, &keys, 8, self.epoch as f64)
            .unwrap();
        self.kv.release(id, true);
    }

    fn digest(&mut self, full: bool) -> LoadDigest {
        let summary = if full {
            // Pre-PR cost: rebuild the summary from the hash index (the
            // incremental sorted mirror did not exist before this PR).
            PrefixSummary::Full(self.kv.cached_key_sample_rebuild(usize::MAX))
        } else {
            let (added, removed) = self.kv.take_key_churn().expect("churn enabled");
            PrefixSummary::Delta { added, removed }
        };
        LoadDigest {
            replica: self.replica,
            clock: self.epoch as f64,
            queued_online: 0,
            running_online: 0,
            running_offline: 0,
            pool_backlog: 0,
            pending_prefill_tokens: 0,
            free_blocks: 1000,
            block_size: 16,
            draining: false,
            degraded: false,
            summary,
        }
    }
}

fn bench_digest_sync(h: &mut Harness, replicas: usize, variant: &str) {
    const WARM_KEYS: usize = 5000;
    let full = variant == "baseline";
    let cfg = SystemConfig::a100_llama8b();
    let mut router = Router::new(TimeModel::new(cfg.time_model), 16);
    let mut reps: Vec<SyncReplica> = (0..replicas)
        .map(|r| SyncReplica::new(r, WARM_KEYS, !full))
        .collect();
    // Initial full sync for both protocols (the delta path's base state).
    for rep in &mut reps {
        let d = rep.digest(true);
        router.sync(d);
    }
    if !full {
        for rep in &mut reps {
            let _ = rep.kv.take_key_churn();
        }
    }
    h.bench(
        &format!("digest sync [{variant}] ({replicas} replicas x {WARM_KEYS} keys, churn 8)"),
        "digest-sync",
        variant,
        replicas,
        40,
        || {
            for rep in &mut reps {
                rep.churn();
                let d = rep.digest(full);
                router.sync(d);
            }
            std::hint::black_box(router.index.total_keys());
        },
    );
}

// ---- kv manager: bucketed victim index vs BTreeSet oracle ------------------

/// KV pair problem sizes in blocks (the `--gate-kv` matrix).
const KV_SIZES: [usize; 3] = [1_000, 16_000, 64_000];
/// Paths with a (baseline, incremental) pair the kv gate asserts on.
const KV_GATE_PATHS: [&str; 4] = [
    "kv-alloc-release",
    "kv-availability",
    "kv-requeue-storm",
    "kv-evict",
];

// ---- gate-coverage manifest (echo-lint G1) ---------------------------------
//
// Every bench path emitted below must be listed exactly once across these
// two tables: either a `--gate-*` assertion enforces it in CI, or the
// ungated table documents why not. `echo lint` cross-checks the tables
// against the actual `.bench(...)`/`.bench_fixed(...)` call sites — a new
// bench pair that lands in neither table fails the lint job, and a stale
// entry whose bench was removed fails it too.

/// Paths asserted by a `--gate-*` flag (`--gate-kv` covers the four KV
/// pairs across `KV_SIZES`; fleet/obs/faults gate their single path).
const GATED_PAIRS: [&str; 10] = [
    "kv-alloc-release",
    "kv-availability",
    "kv-requeue-storm",
    "kv-evict",
    "fleet-step",
    "obs-step",
    "faults-step",
    "slo-tick",
    "journal-step",
    "health-tick",
];

/// Measured-but-ungated paths, each with the reason no CI assertion holds
/// it: these are tracked in the bench report for trend review instead.
const UNGATED_PAIRS: [(&str, &str); 9] = [
    (
        "scheduler-decision",
        "speedup printed for review; absolute decision cost is CI-load-dependent",
    ),
    (
        "digest-sync",
        "speedup printed for review; pair is minutes-scale only at fleet sizes CI cannot host",
    ),
    (
        "kv-requeue-scatter",
        "documented worst case (mid-bucket insert); expected near 1x, kept visible not gated",
    ),
    ("kv-peek", "read-only probe with no baseline pair to gate against"),
    (
        "kv-evict-preview",
        "counter-walk preview; sub-microsecond and noise-dominated on shared runners",
    ),
    ("radix", "router index micro-cost tracked in the report; no before/after pair"),
    (
        "radix-churn",
        "delta-apply micro-cost tracked in the report; no before/after pair",
    ),
    ("estimator", "fit cost recorded at two sizes for the report only"),
    (
        "content-keys",
        "hashing micro-cost; PR 5 recorded the win once, trend lives in the report",
    ),
];

/// Baseline (pre-PR `OracleKvManager`) or incremental (`KvManager`) behind
/// one dispatch surface, so both sides of every pair run the *same* op
/// closure.
enum KvImpl {
    Incremental(KvManager),
    Baseline(OracleKvManager),
}

impl KvImpl {
    fn new(variant: &str, capacity: usize) -> Self {
        match variant {
            "incremental" => {
                KvImpl::Incremental(KvManager::new(capacity, 16, EvictionPolicy::TaskAware))
            }
            _ => KvImpl::Baseline(OracleKvManager::new(capacity, 16, EvictionPolicy::TaskAware)),
        }
    }

    fn allocate(
        &mut self,
        req: u64,
        class: TaskClass,
        keys: &[u128],
        total: usize,
        now: f64,
    ) -> Option<usize> {
        match self {
            KvImpl::Incremental(m) => m.allocate(req, class, keys, total, now),
            KvImpl::Baseline(m) => m.allocate(req, class, keys, total, now),
        }
    }

    fn release(&mut self, req: u64, finished: bool) {
        match self {
            KvImpl::Incremental(m) => m.release(req, finished),
            KvImpl::Baseline(m) => m.release(req, finished),
        }
    }

    fn register_future(&mut self, keys: &[u128]) {
        match self {
            KvImpl::Incremental(m) => m.register_future(keys),
            KvImpl::Baseline(m) => m.register_future(keys),
        }
    }

    fn unregister_future(&mut self, keys: &[u128]) {
        match self {
            KvImpl::Incremental(m) => m.unregister_future(keys),
            KvImpl::Baseline(m) => m.unregister_future(keys),
        }
    }

    fn availability(&self) -> Availability {
        match self {
            KvImpl::Incremental(m) => m.availability(),
            KvImpl::Baseline(m) => m.availability(),
        }
    }
}

/// Warm `n` keyed, evictable (released, RC=0) blocks into the cache in
/// slabs. Returns the keys in release order (oldest LAT first).
fn kv_warm(kv: &mut KvImpl, n: usize) -> Vec<u128> {
    let mut keys = Vec::with_capacity(n);
    let mut id = 5_000_000u64;
    let mut left = n;
    let mut t = 0.0f64;
    while left > 0 {
        let slab = 250.min(left);
        id += 1;
        t += 1.0;
        let base = (9u128 << 100) | ((id as u128) << 16);
        let slab_keys: Vec<u128> = (0..slab as u128).map(|i| base | i).collect();
        kv.allocate(id, TaskClass::Offline, &slab_keys, slab, t).unwrap();
        kv.release(id, true);
        keys.extend_from_slice(&slab_keys);
        left -= slab;
    }
    keys
}

/// The four gated KV pairs at one problem size. Fixed iteration counts
/// (`bench_fixed`): `--gate-kv` runs in the `--quick` CI smoke and still
/// needs real medians.
fn bench_kv_pairs(h: &mut Harness, size: usize, variant: &str) {
    // allocate+release cycle: pin 32 warm hit-blocks, release them back —
    // the steady admission path. The baseline pays an O(size) availability
    // scan inside every allocate plus triple hit resolution and BTreeSet
    // churn; the bucketed index pays O(1) per block.
    let mut kv = KvImpl::new(variant, size + 64);
    let warm = kv_warm(&mut kv, size);
    let cycle: Vec<u128> = warm[warm.len() - 32..].to_vec();
    let mut id = 0u64;
    let mut now = 1_000.0;
    h.bench_fixed(
        &format!("kv allocate+release [{variant}] (32 hot blocks, {size} cached)"),
        "kv-alloc-release",
        variant,
        size,
        100,
        || {
            id += 1;
            now += 0.01;
            kv.allocate(id, TaskClass::Offline, &cycle, 32, now).unwrap();
            kv.release(id, true);
        },
    );

    // availability(): incremental counters vs the priority-0 prefix scan.
    h.bench_fixed(
        &format!("kv availability [{variant}] ({size} evictable blocks)"),
        "kv-availability",
        variant,
        size,
        300,
        || {
            std::hint::black_box(kv.availability());
        },
    );

    // register/unregister requeue storm: future-RC churn moves blocks
    // between priority buckets every call. Half the keys are the *oldest*
    // cached content and half the *newest*, so the gate covers both ends
    // of the two-ended ordered insert (head prepends and tail appends),
    // not just the monotonic-release best case.
    let mut storm: Vec<u128> = warm[..32].to_vec();
    storm.extend_from_slice(&warm[warm.len() - 32..]);
    h.bench_fixed(
        &format!("kv requeue storm [{variant}] (64-key RC churn, {size} cached)"),
        "kv-requeue-storm",
        variant,
        size,
        150,
        || {
            kv.register_future(&storm);
            kv.unregister_future(&storm);
        },
    );

    // Scatter storm (documented worst case, measured but NOT gated): RC
    // churn on middle-aged cached keys re-inserts at mid-bucket positions,
    // where the ordered intrusive list pays O(distance-to-nearer-end) per
    // link vs the oracle's O(log n) BTreeSet — the one pattern the bucket
    // design trades away. Kept visible in BENCH_PR10.json so the perf
    // trajectory tracks it; a skip-hint can reclaim it if real workloads
    // ever look like this.
    let mid = warm.len() / 2;
    let scatter: Vec<u128> = warm[mid - 32..mid + 32].to_vec();
    h.bench_fixed(
        &format!("kv requeue scatter [{variant}] (64 mid-aged keys, {size} cached)"),
        "kv-requeue-scatter",
        variant,
        size,
        10,
        || {
            kv.register_future(&scatter);
            kv.unregister_future(&scatter);
        },
    );

    // eviction churn: a full cache forced to evict 64 victims per op (the
    // memory-pressure steady state). Baseline: BTreeSet pop + scan;
    // bucketed: head pops.
    let mut kv = KvImpl::new(variant, size);
    kv_warm(&mut kv, size);
    let mut epoch = 0u64;
    h.bench_fixed(
        &format!("kv eviction churn [{variant}] (evict+recache 64, {size} blocks)"),
        "kv-evict",
        variant,
        size,
        60,
        || {
            epoch += 1;
            let keys: Vec<u128> = (0..64).map(|i| ((epoch as u128) << 32) | i).collect();
            kv.allocate(epoch, TaskClass::Offline, &keys, 64, 2_000.0 + epoch as f64)
                .unwrap();
            kv.release(epoch, true);
        },
    );
}

// ---- kv lookups / radix / estimator / content keys -------------------------

fn bench_kv_ops(h: &mut Harness) {
    // Prefix lookup on a warm cache (no pre-PR pair: the path was already
    // a plain hash probe; the fast hasher speeds it transparently).
    let mut kv = KvManager::new(8192, 16, EvictionPolicy::TaskAware);
    let keys: Vec<u128> = (0..512).map(|i| (7u128 << 96) | i).collect();
    kv.register_future(&keys);
    kv.allocate(1, TaskClass::Offline, &keys, 512, 0.0).unwrap();
    kv.release(1, false);
    h.bench(
        "kv peek_prefix (512 cached blocks)",
        "kv-peek",
        "incremental",
        512,
        2000,
        || {
            std::hint::black_box(kv.peek_prefix(&keys));
        },
    );
    h.bench(
        "kv eviction_preview (64 victims)",
        "kv-evict-preview",
        "incremental",
        64,
        2000,
        || {
            std::hint::black_box(kv.eviction_preview(64));
        },
    );
}

fn bench_radix(h: &mut Harness) {
    let mut idx = RadixIndex::default();
    for r in 0..1000u64 {
        let group = r % 20;
        let keys: Vec<u128> = (0..64)
            .map(|i| if i < 48 { ((group as u128) << 32) | i } else { ((r as u128) << 48) | i })
            .collect();
        idx.insert(r, keys);
    }
    let mut kv = KvManager::new(4096, 16, EvictionPolicy::TaskAware);
    let warm: Vec<u128> = (0..48).map(|i| (3u128 << 32) | i).collect();
    kv.register_future(&warm);
    kv.allocate(1_000_001, TaskClass::Offline, &warm, 48, 0.0).unwrap();
    kv.release(1_000_001, false);
    h.bench(
        "radix best_cached (1000 reqs, 48-deep warm path)",
        "radix",
        "incremental",
        1000,
        1000,
        || {
            std::hint::black_box(idx.best_cached(&kv));
        },
    );
    let mut next = 10_000u64;
    h.bench(
        "radix insert+remove (64-key path, arena)",
        "radix-churn",
        "incremental",
        64,
        2000,
        || {
            next += 1;
            let keys: Vec<u128> = (0..64).map(|i| ((next as u128) << 40) | i).collect();
            idx.insert(next, keys);
            idx.remove(next);
        },
    );
}

fn bench_estimator(h: &mut Harness) {
    let tm = TimeModel::new(SystemConfig::a100_llama8b().time_model);
    let shape = BatchShape {
        prefills: vec![PrefillItem { chunk: 512, context: 1024 }],
        decode_lens: (0..64).map(|i| 500 + i * 13).collect(),
    };
    h.bench(
        "estimator batch_time re-scan (1 prefill + 64 decodes)",
        "estimator",
        "baseline",
        64,
        20_000,
        || {
            std::hint::black_box(tm.batch_time(&shape));
        },
    );
    let mut trial = TrialShape::from_shape(&tm, shape.clone());
    h.bench(
        "estimator trial push/score/undo (O(1) aggregates)",
        "estimator",
        "incremental",
        64,
        20_000,
        || {
            let u = trial.push_decode(1333);
            std::hint::black_box(tm.batch_time_inc(&trial));
            trial.undo(u);
        },
    );
}

fn bench_content_keys(h: &mut Harness) {
    let r = Request::new(
        42,
        TaskClass::Offline,
        0.0,
        PromptSpec::sim(2048, Some((9, 1536))),
        32,
    );
    h.bench(
        "content keys, direct chain hash (2048-token prompt)",
        "content-keys",
        "baseline",
        2048,
        5000,
        || {
            std::hint::black_box(r.prompt.content_keys(42, 2048, 16).len());
        },
    );
    let _ = r.content_key_path(16); // populate the intern cache
    h.bench(
        "content keys, interned accessor (same prompt)",
        "content-keys",
        "incremental",
        2048,
        5000,
        || {
            std::hint::black_box(r.content_key_path(16).len());
        },
    );
}

fn bench_sim_iterations(quick: bool) {
    // End-to-end through the serving API: submissions and stepping go
    // through the same `Serve` front door every driver uses.
    let mut cfg = SystemConfig::a100_llama8b();
    cfg.scheduler.kind = SchedulerKind::Echo;
    let backend = SimBackend::new(TimeModel::new(cfg.time_model), 2, 0.0);
    let mut front = EngineServe::new(Engine::new(cfg, backend));
    let mut rng = Rng::new(2);
    let mut scratch = RequestStore::new();
    let batch = synthesize(
        &DatasetSpec::loogle_qa_short(),
        if quick { 40 } else { 400 },
        TaskClass::Offline,
        0.0,
        &mut scratch,
        &mut rng,
    );
    for &id in &batch.ids {
        let r = scratch.get(id);
        front
            .submit(SubmitSpec::offline(r.prompt.clone(), r.max_new_tokens))
            .unwrap();
    }
    for i in 0..(if quick { 50 } else { 500 }) {
        front
            .submit(SubmitSpec::online(PromptSpec::sim(300, None), 32).at(i as f64 * 0.4))
            .unwrap();
    }
    let horizon = if quick { 10.0 } else { 120.0 };
    let t0 = Instant::now();
    let mut iters = 0usize;
    while front.engine.clock < horizon {
        if !front.pump(&mut NullSink).unwrap() {
            break;
        }
        iters += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{:<62} {:>9.0} iters/s  ({} iters, {:.2}s wall, {:.0}s simulated)",
        "end-to-end sim engine (via Serve)",
        iters as f64 / wall.max(1e-9),
        iters,
        wall,
        front.engine.clock
    );
}

// ---- fleet stepping: serial advance vs scoped worker pool -----------------

fn fleet_online(replicas: usize, horizon: f64, seed: u64) -> Vec<OnlineJob> {
    let n = replicas * 8;
    (0..n)
        .map(|i| OnlineJob {
            at: (i as f64 + 0.5) * horizon / (n as f64 + 1.0),
            prompt: PromptSpec::sim(160 + (i % 5) * 40, Some((seed ^ (i % 8) as u64, 96))),
            max_new_tokens: 16 + (i % 4) * 8,
        })
        .collect()
}

/// One op = build a fleet, flood its backlog, and replay a short online
/// trace to the horizon. Serial (`t1`) vs worker-pool (`tN`) pairs share
/// identical inputs; construction cost is included on both sides. Macro
/// bench: the iteration count is fixed (not `--quick`-scaled), so the CI
/// fleet gate sees real timings. The per-replica load (12 offline jobs +
/// 8 decode-heavy online requests) keeps every quantum busy enough that
/// the advance phase dominates fleet construction and the per-quantum
/// worker spawns — the gate below compares medians, so it needs real
/// margin, not a coin flip, on loaded shared runners.
fn bench_fleet_step(h: &mut Harness, replicas: usize, threads: usize) {
    let variant = format!("t{threads}");
    let horizon = 2.0;
    let online = fleet_online(replicas, horizon, 0xF1EE7);
    let offline = offline_jobs(&DatasetSpec::loogle_qa_short().scaled(0.05), replicas * 12, 23);
    h.bench(
        &format!("fleet step [{variant}] ({replicas} replicas, {horizon}s horizon)"),
        "fleet-step",
        &variant,
        replicas,
        2, // fixed macro-op count (the harness `.max(2)` floor keeps it 2 in both modes)
        || {
            let mut base = SystemConfig::a100_llama8b();
            base.cache.capacity_tokens = 30_000;
            base.scheduler.max_batch = 16;
            let mut cc = ClusterConfig::new(base, replicas);
            cc.threads = threads;
            let mut sim = ClusterSim::new(cc);
            sim.submit_offline_backlog(offline.iter().cloned());
            let report = sim.run(&online, horizon).unwrap();
            std::hint::black_box(report.aggregate.iterations);
        },
    );
}

// ---- engine step allocation count (zero-alloc steady state) ---------------

struct AllocReport {
    /// Allocations on a transition-free (steady-state) step: must be 0 in
    /// release builds.
    steady: u64,
    /// Mean allocations per step over the window (KV block growth at block
    /// boundaries and periodic predictor samples land here).
    mean: f64,
}

fn bench_step_allocs() -> AllocReport {
    let mut cfg = SystemConfig::a100_llama8b();
    cfg.scheduler.kind = SchedulerKind::Echo;
    cfg.cache.capacity_tokens = 50_000;
    let backend = SimBackend::new(TimeModel::new(cfg.time_model), 7, 0.0);
    let mut e = Engine::new(cfg, backend);
    e.set_sample_interval(f64::INFINITY);
    for _ in 0..8 {
        let id = e.store.fresh_id();
        e.submit_offline(Request::new(
            id,
            TaskClass::Offline,
            0.0,
            PromptSpec::sim(200, None),
            600,
        ));
    }
    // Warm up: admissions + prefill; scratch capacities peak here.
    for _ in 0..64 {
        e.step().unwrap();
    }
    let growth = e.step_alloc_growth();
    let n = 256u64;
    let mut steady = u64::MAX;
    let mut total = 0u64;
    for _ in 0..n {
        let before = ALLOCS.load(Ordering::Relaxed);
        e.step().unwrap();
        let d = ALLOCS.load(Ordering::Relaxed) - before;
        steady = steady.min(d);
        total += d;
    }
    assert_eq!(
        e.step_alloc_growth(),
        growth,
        "steady-state steps must not grow the recycled step buffers"
    );
    let mean = total as f64 / n as f64;
    println!(
        "{:<62} {steady:>6} allocs/steady step (mean {mean:.2} incl. block growth)",
        "engine step allocations (8 offline decodes)"
    );
    if cfg!(not(debug_assertions)) {
        assert_eq!(
            steady, 0,
            "the engine step loop must be allocation-free in steady state"
        );
    }
    AllocReport { steady, mean }
}

// ---- obs: trace-hook overhead on the engine step loop ----------------------

/// Shared engine setup for the obs-step pair: 8 long offline decodes past
/// their admission transient, so every measured step is the steady decode
/// loop where the trace hooks sit. `max_new_tokens` is sized so the engine
/// never goes idle inside the measured window (warmup + 7 runs x 500 steps
/// < 5000 decode tokens per request).
fn obs_step_engine(traced: bool) -> Engine<SimBackend> {
    let mut cfg = SystemConfig::a100_llama8b();
    cfg.scheduler.kind = SchedulerKind::Echo;
    cfg.cache.capacity_tokens = 50_000;
    let backend = SimBackend::new(TimeModel::new(cfg.time_model), 7, 0.0);
    let mut e = Engine::new(cfg, backend);
    e.set_sample_interval(f64::INFINITY);
    if traced {
        e.enable_trace(echo::obs::DEFAULT_TRACE_EVENTS);
    }
    for _ in 0..8 {
        let id = e.store.fresh_id();
        e.submit_offline(Request::new(
            id,
            TaskClass::Offline,
            0.0,
            PromptSpec::sim(200, None),
            5000,
        ));
    }
    // Warm up: admissions + prefill transients, lazy histogram buckets, and
    // the recycled step buffers all settle here.
    for _ in 0..64 {
        e.step().unwrap();
    }
    e
}

/// The PR 6 pair: engine step with tracing disabled (`baseline` — the
/// `Option<TraceRing>` branch folds to a skipped block) vs enabled
/// (`incremental` — every step records an iteration span plus lifecycle and
/// KV-delta events into the pre-sized ring). The hooks are designed to cost
/// nothing measurable either way; `--gate-obs` holds the enabled side to
/// the shared 5% noise band, which transitively bounds the disabled side.
fn bench_obs_step(h: &mut Harness, variant: &str) {
    let traced = variant == "incremental";
    let mode = if traced { "tracing on" } else { "tracing off" };
    let mut e = obs_step_engine(traced);
    h.bench_fixed(
        &format!("engine step [{mode}] (8 offline decodes)"),
        "obs-step",
        variant,
        8,
        500,
        || {
            e.step().unwrap();
        },
    );
}

// ---- faults: injector-hook overhead on the engine step loop ----------------

/// The PR 7 pair: engine step with no fault schedule installed (`baseline`
/// — the `Option<ReplicaFaults>` hook folds to a skipped branch) vs a
/// non-empty schedule whose events never fire (`incremental` — a straggler
/// window parked in the far future, so every step pays the full hook
/// dispatch but injection never triggers). The schedule must be non-empty:
/// `install_faults` drops empty schedules, which would make both sides
/// identical and the gate vacuous. `--gate-faults` holds the armed side to
/// the shared 5% noise band.
fn bench_faults_step(h: &mut Harness, variant: &str) {
    let armed = variant == "incremental";
    let mode = if armed { "faults armed" } else { "faults off" };
    let mut e = obs_step_engine(false);
    if armed {
        let plan = echo::faults::FaultPlan {
            events: vec![echo::faults::FaultEvent::Slowdown {
                at: 1.0e12,
                until: 2.0e12,
                replica: 0,
                factor: 4.0,
            }],
            seed: 0,
        };
        e.install_faults(plan.for_replica(0));
        assert!(e.faults_installed(), "the armed side must carry a schedule");
    }
    h.bench_fixed(
        &format!("engine step [{mode}] (8 offline decodes)"),
        "faults-step",
        variant,
        8,
        500,
        || {
            e.step().unwrap();
        },
    );
}

// ---- slo guard: controller overhead on the fleet quantum -------------------

/// The PR 9 pair: one fleet quantum (replica advance + single-threaded
/// coordinator finish) with no guard configured (`baseline` — the
/// `Option<SloGuard>` tick is one skipped branch and every engine-side
/// actuator an untaken compare against the `usize::MAX` sentinel) vs the
/// guard armed but idle (`incremental` — target 0.0 with a `usize::MAX`
/// ceiling: no window can ever miss and the AIMD cap stays at the disabled
/// sentinel, so the controller folds the fleet's latency histograms and
/// runs the full control law every quantum without ever actuating). The
/// armed-idle fleet is bit-exact with the disarmed one by construction
/// (see `cluster::sim` tests), so both sides do identical scheduling work
/// and the ratio isolates pure controller cost. `--gate-slo` holds the
/// armed side to the shared 5% noise band.
fn bench_slo_tick(h: &mut Harness, variant: &str) {
    let armed = variant == "incremental";
    let mode = if armed { "guard armed-idle" } else { "guard off" };
    let mut base = SystemConfig::a100_llama8b();
    base.seed = 11;
    base.cache.capacity_tokens = 30_000;
    base.scheduler.max_batch = 16;
    let mut cc = ClusterConfig::new(base, 4);
    if armed {
        cc.guard = Some(SloGuardConfig {
            target: 0.0,
            cap_max: usize::MAX,
            ..SloGuardConfig::default()
        });
    }
    let mut sim = ClusterSim::new(cc);
    sim.submit_offline_backlog(offline_jobs(&DatasetSpec::loogle_qa_short(), 2000, 11));
    sim.begin();
    let dt = 0.25;
    let mut t = 0.0;
    h.bench_fixed(
        &format!("fleet quantum [{mode}] (4 replicas, offline flood)"),
        "slo-tick",
        variant,
        4,
        400,
        || {
            let t_end = t + dt;
            sim.advance_replicas(t, t_end).unwrap();
            sim.finish_quantum(t_end);
            t = t_end;
        },
    );
}

// ---- durable sessions: journal + health-monitor overhead (PR 10) -----------

/// The PR 10 pump pair: the single-engine serve pump with the
/// durable-session journal disarmed (`baseline` — the journal `Option` is
/// never even constructed) vs armed but idle (`incremental` — the journal
/// exists but no submit carried an idempotency key, so every pump pays
/// exactly the `is_empty()` fast path and never materializes events).
/// `--gate-durable` holds the armed side to the shared 5% noise band.
fn bench_journal_step(h: &mut Harness, variant: &str) {
    let armed = variant == "incremental";
    let mode = if armed { "journal armed-idle" } else { "journal off" };
    let cfg = {
        let mut c = SystemConfig::a100_llama8b();
        c.seed = 13;
        c.scheduler.max_batch = 16;
        c
    };
    let backend = SimBackend::new(TimeModel::new(cfg.time_model), 13, 0.0);
    let mut front = EngineServe::new(Engine::new(cfg, backend));
    if armed {
        assert!(front.arm_journal(JournalConfig::default()), "engine front arms");
        assert!(
            front.journal().is_some_and(|j| j.is_empty()),
            "the armed side must stay idle (no keyed submits)"
        );
    }
    // A deep keyless offline pool so every pump advances real work and the
    // journal (when armed) stays empty.
    for i in 0..64usize {
        front
            .submit(SubmitSpec::offline(
                PromptSpec::sim(600 + (i % 7) * 100, None),
                32,
            ))
            .unwrap();
    }
    let mut sink = NullSink;
    h.bench_fixed(
        &format!("serve pump [{mode}] (64-job offline pool)"),
        "journal-step",
        variant,
        8,
        500,
        || {
            front.pump(&mut sink).unwrap();
        },
    );
}

/// The PR 10 quantum pair: one fleet quantum with no gray-failure monitor
/// (`baseline` — the health tick is one `is_none` branch) vs the monitor
/// armed on a healthy fleet (`incremental` — every quantum folds each
/// replica's drift window against the coordinator clock; the estimator
/// tracks actuals, so no window ever judges bad and no transition fires).
/// The armed-healthy fleet is bit-exact with the disarmed one by
/// construction (see `cluster::sim` tests), so the ratio isolates pure
/// monitor cost. `--gate-durable` holds the armed side to the shared 5%
/// noise band.
fn bench_health_tick(h: &mut Harness, variant: &str) {
    let armed = variant == "incremental";
    let mode = if armed { "monitor armed-healthy" } else { "monitor off" };
    let mut base = SystemConfig::a100_llama8b();
    base.seed = 11;
    base.cache.capacity_tokens = 30_000;
    base.scheduler.max_batch = 16;
    let mut cc = ClusterConfig::new(base, 4);
    if armed {
        cc.health = Some(HealthConfig::default());
    }
    let mut sim = ClusterSim::new(cc);
    sim.submit_offline_backlog(offline_jobs(&DatasetSpec::loogle_qa_short(), 2000, 11));
    sim.begin();
    let dt = 0.25;
    let mut t = 0.0;
    h.bench_fixed(
        &format!("fleet quantum [{mode}] (4 replicas, offline flood)"),
        "health-tick",
        variant,
        4,
        400,
        || {
            let t_end = t + dt;
            sim.advance_replicas(t, t_end).unwrap();
            sim.finish_quantum(t_end);
            t = t_end;
        },
    );
}

#[cfg(not(feature = "runtime"))]
fn bench_pjrt() {
    println!("pjrt step: skipped (built without the `runtime` feature)");
}

#[cfg(feature = "runtime")]
fn bench_pjrt() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("pjrt step: skipped (run `make artifacts`)");
        return;
    }
    let mut rt = echo::runtime::ModelRuntime::load(&dir).unwrap();
    for &bucket in &[1usize, 16, 64] {
        let secs = rt.bench_step(bucket, 128, 10).unwrap();
        let toks = rt.manifest.max_batch * bucket;
        println!(
            "{:<62} {:>9.2} ms/step  ({} tokens -> {:.0} tok/s)",
            format!("pjrt step bucket c{bucket} (context 128, all slots)"),
            secs * 1e3,
            toks,
            toks as f64 / secs
        );
    }
}

// ---- reporting -----------------------------------------------------------

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else {
        format!("{:.3} ms", ns / 1e6)
    }
}

/// Markdown before/after table for EXPERIMENTS.md §Perf.
fn perf_table(h: &Harness) -> String {
    let mut out = String::new();
    out.push_str("| path | size | before (median/op) | after (median/op) | speedup |\n");
    out.push_str("|---|---|---|---|---|\n");
    let mut pairs: Vec<(&str, usize)> = vec![
        ("scheduler-decision", 100usize),
        ("scheduler-decision", 1000),
        ("scheduler-decision", 5000),
        ("digest-sync", 1),
        ("digest-sync", 4),
        ("digest-sync", 16),
    ];
    for path in KV_GATE_PATHS {
        for &size in &KV_SIZES {
            pairs.push((path, size));
        }
    }
    for &size in &KV_SIZES {
        pairs.push(("kv-requeue-scatter", size));
    }
    pairs.push(("estimator", 64));
    pairs.push(("content-keys", 2048));
    // obs-step "before" is tracing off and "after" is tracing on, so the
    // interesting number is the speedup staying at ~1.0x. Same story for
    // faults-step: "before" is no injector hook, "after" is an installed
    // (never-firing) fault schedule.
    pairs.push(("obs-step", 8));
    pairs.push(("faults-step", 8));
    for (path, size) in pairs {
        let (Some(b), Some(i)) = (
            h.median_of(path, "baseline", size),
            h.median_of(path, "incremental", size),
        ) else {
            continue;
        };
        out.push_str(&format!(
            "| {path} | {size} | {} | {} | {:.1}x |\n",
            fmt_ns(b),
            fmt_ns(i),
            b / i.max(1e-9)
        ));
    }
    for &replicas in &[4usize, 16, 64] {
        let (Some(b), Some(i)) = (
            h.median_of("fleet-step", "t1", replicas),
            h.median_of("fleet-step", "t4", replicas),
        ) else {
            continue;
        };
        out.push_str(&format!(
            "| fleet-step (serial vs 4 threads) | {replicas} | {} | {} | {:.1}x |\n",
            fmt_ns(b),
            fmt_ns(i),
            b / i.max(1e-9)
        ));
    }
    for (path, size, label) in [
        ("radix", 1000usize, "radix best_cached"),
        ("radix-churn", 64, "radix insert+remove"),
        ("kv-peek", 512, "kv peek_prefix"),
    ] {
        if let Some(m) = h.median_of(path, "incremental", size) {
            out.push_str(&format!("| {label} | {size} | — | {} | — |\n", fmt_ns(m)));
        }
    }
    out
}

fn write_experiments(path: &str, table: &str) {
    const BEGIN: &str = "<!-- perf:begin -->";
    const END: &str = "<!-- perf:end -->";
    // `cargo bench` sets cwd to the package root (rust/); EXPERIMENTS.md
    // lives one level up. Fall back there if the given path is missing.
    let path: String = if std::path::Path::new(path).exists() {
        path.to_string()
    } else {
        format!("../{path}")
    };
    let path = path.as_str();
    let Ok(text) = std::fs::read_to_string(path) else {
        eprintln!("--write-experiments: cannot read {path}");
        return;
    };
    let (Some(b), Some(e)) = (text.find(BEGIN), text.find(END)) else {
        eprintln!("--write-experiments: {path} has no perf markers");
        return;
    };
    if e < b {
        eprintln!("--write-experiments: malformed markers in {path}");
        return;
    }
    let new = format!(
        "{}{}\n{}\n{}",
        &text[..b],
        BEGIN,
        table.trim_end(),
        &text[e..]
    );
    if std::fs::write(path, new).is_ok() {
        println!("wrote §Perf table to {path}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let gate_fleet = args.iter().any(|a| a == "--gate-fleet");
    let gate_kv = args.iter().any(|a| a == "--gate-kv");
    let gate_obs = args.iter().any(|a| a == "--gate-obs");
    let gate_faults = args.iter().any(|a| a == "--gate-faults");
    let gate_slo = args.iter().any(|a| a == "--gate-slo");
    let gate_durable = args.iter().any(|a| a == "--gate-durable");
    let json_path = args
        .iter()
        .position(|a| a == "--bench-json")
        .map(|i| args.get(i + 1).cloned().unwrap_or_else(|| "BENCH_PR10.json".into()));
    let experiments_path = args
        .iter()
        .position(|a| a == "--write-experiments")
        .map(|i| args.get(i + 1).cloned().unwrap_or_else(|| "EXPERIMENTS.md".into()));

    println!("== microbench: L3 coordinator hot paths ==\n");
    let mut h = Harness::new(quick);
    for pool in [100usize, 1000, 5000] {
        for variant in ["baseline", "incremental"] {
            bench_scheduler_decision(&mut h, pool, variant);
        }
    }
    for replicas in [1usize, 4, 16] {
        for variant in ["baseline", "incremental"] {
            bench_digest_sync(&mut h, replicas, variant);
        }
    }
    for size in KV_SIZES {
        for variant in ["baseline", "incremental"] {
            bench_kv_pairs(&mut h, size, variant);
        }
    }
    for replicas in [4usize, 16, 64] {
        for threads in [1usize, 2, 4, 8] {
            bench_fleet_step(&mut h, replicas, threads);
        }
    }
    let alloc = bench_step_allocs();
    for variant in ["baseline", "incremental"] {
        bench_obs_step(&mut h, variant);
    }
    for variant in ["baseline", "incremental"] {
        bench_faults_step(&mut h, variant);
    }
    for variant in ["baseline", "incremental"] {
        bench_slo_tick(&mut h, variant);
    }
    for variant in ["baseline", "incremental"] {
        bench_journal_step(&mut h, variant);
    }
    for variant in ["baseline", "incremental"] {
        bench_health_tick(&mut h, variant);
    }
    bench_kv_ops(&mut h);
    bench_radix(&mut h);
    bench_estimator(&mut h);
    bench_content_keys(&mut h);
    bench_sim_iterations(quick);
    bench_pjrt();

    println!();
    for (path, size) in [("scheduler-decision", 5000usize), ("digest-sync", 16)] {
        if let Some(s) = h.speedup(path, size) {
            println!("speedup {path}@{size}: {s:.1}x (gate: >= 2x)");
        }
    }
    for path in KV_GATE_PATHS {
        for &size in &KV_SIZES {
            if let Some(s) = h.speedup(path, size) {
                println!("speedup {path}@{size}: {s:.2}x");
            }
        }
    }
    for replicas in [4usize, 16, 64] {
        for threads in [2usize, 4, 8] {
            if let Some(s) = fleet_speedup(&h, replicas, threads) {
                println!("speedup fleet-step@{replicas}x{threads}: {s:.2}x");
            }
        }
    }
    if let Some(s) = h.speedup("obs-step", 8) {
        println!("speedup obs-step@8 (untraced vs traced): {s:.2}x");
    }
    if let Some(s) = h.speedup("faults-step", 8) {
        println!("speedup faults-step@8 (hook-free vs armed): {s:.2}x");
    }
    if let Some(s) = h.speedup("slo-tick", 4) {
        println!("speedup slo-tick@4 (guardless vs armed-idle): {s:.2}x");
    }
    if let Some(s) = h.speedup("journal-step", 8) {
        println!("speedup journal-step@8 (disarmed vs armed-idle): {s:.2}x");
    }
    if let Some(s) = h.speedup("health-tick", 4) {
        println!("speedup health-tick@4 (unmonitored vs armed-healthy): {s:.2}x");
    }
    if gate_fleet {
        let s = fleet_speedup(&h, 16, 4).expect("fleet-step@16x4 must be measured");
        println!("fleet gate: parallel (4 threads) vs serial at 16 replicas = {s:.2}x");
        // 5% noise band for shared CI runners: a genuinely serialized
        // parallel path (lock contention, lost parallelism) lands far
        // below this; healthy runs land well above 1.0x.
        assert!(
            s >= 0.95,
            "parallel fleet stepping must not be slower than serial at \
             16 replicas / 4 threads (measured {s:.2}x, gate 0.95x)"
        );
    }
    if gate_kv {
        let mut failures = Vec::new();
        for path in KV_GATE_PATHS {
            for &size in &KV_SIZES {
                let s = h
                    .speedup(path, size)
                    .unwrap_or_else(|| panic!("{path}@{size} must be measured"));
                println!("kv gate: {path}@{size} = {s:.2}x vs oracle");
                // Same 5% noise band as the fleet gate: healthy pairs land
                // at 2x+ (the availability/eviction pairs orders of
                // magnitude above), so anything under the band is a real
                // regression, not shared-runner jitter.
                if s < 0.95 {
                    failures.push(format!("{path}@{size} = {s:.2}x"));
                }
            }
        }
        assert!(
            failures.is_empty(),
            "bucketed KV manager must not lose to the oracle baseline on \
             any pair: {failures:?}"
        );
        if cfg!(not(debug_assertions)) {
            assert_eq!(
                alloc.steady, 0,
                "kv gate: the steady-state engine step must stay allocation-free"
            );
        }
    }

    if gate_obs {
        let s = h
            .speedup("obs-step", 8)
            .expect("obs-step pair must be measured");
        println!("obs gate: traced vs untraced engine step = {s:.2}x");
        // Same 5% noise band as the fleet/kv gates: the per-step trace cost
        // is a handful of field writes into a pre-sized ring, orders of
        // magnitude below the scheduler/estimator work in a step, so a
        // below-band reading means a hook started doing real work (or
        // allocating) on the hot path.
        assert!(
            s >= 0.95,
            "enabling tracing must not slow the engine step loop beyond \
             the noise band (measured {s:.2}x, gate 0.95x)"
        );
        if cfg!(not(debug_assertions)) {
            assert_eq!(
                alloc.steady, 0,
                "obs gate: with tracing off the steady-state engine step \
                 must stay allocation-free"
            );
        }
    }

    if gate_faults {
        let s = h
            .speedup("faults-step", 8)
            .expect("faults-step pair must be measured");
        println!("faults gate: armed vs hook-free engine step = {s:.2}x");
        // Same 5% noise band as the other gates: with no event in range the
        // injector is one `Option` check plus a binary probe into a
        // one-element schedule per step — orders of magnitude below the
        // scheduler/estimator work — so a below-band reading means the hook
        // started doing real work (or allocating) on the hot path.
        assert!(
            s >= 0.95,
            "an installed-but-idle fault schedule must not slow the engine \
             step loop beyond the noise band (measured {s:.2}x, gate 0.95x)"
        );
        if cfg!(not(debug_assertions)) {
            assert_eq!(
                alloc.steady, 0,
                "faults gate: with injection disabled the steady-state \
                 engine step must stay allocation-free"
            );
        }
    }

    if gate_slo {
        let s = h
            .speedup("slo-tick", 4)
            .expect("slo-tick pair must be measured");
        println!("slo gate: armed-idle vs guardless fleet quantum = {s:.2}x");
        // Same 5% noise band as the other gates: an idle controller tick is
        // one histogram fold into pre-sized scratch plus a few compares per
        // quantum — orders of magnitude below the replica advance it rides
        // on — so a below-band reading means the guard started doing real
        // work (or allocating) on the coordinator hot path.
        assert!(
            s >= 0.95,
            "an armed-but-idle SLO guard must not slow the fleet quantum \
             beyond the noise band (measured {s:.2}x, gate 0.95x)"
        );
        if cfg!(not(debug_assertions)) {
            assert_eq!(
                alloc.steady, 0,
                "slo gate: with the controller off the steady-state engine \
                 step must stay allocation-free"
            );
        }
    }

    if gate_durable {
        let js = h
            .speedup("journal-step", 8)
            .expect("journal-step pair must be measured");
        let ht = h
            .speedup("health-tick", 4)
            .expect("health-tick pair must be measured");
        println!("durable gate: armed-idle vs disarmed serve pump = {js:.2}x");
        println!("durable gate: armed-healthy vs unmonitored fleet quantum = {ht:.2}x");
        // Same 5% noise band as the other gates: an idle journal is one
        // `is_empty()` check per pump, and a healthy monitor tick is one
        // subtraction + compare per replica per quantum — both orders of
        // magnitude below the scheduling work they ride on, so a
        // below-band reading means durability started doing real work (or
        // allocating) on a hot path.
        assert!(
            js >= 0.95,
            "an armed-but-idle journal must not slow the serve pump beyond \
             the noise band (measured {js:.2}x, gate 0.95x)"
        );
        assert!(
            ht >= 0.95,
            "an armed-but-healthy gray-failure monitor must not slow the \
             fleet quantum beyond the noise band (measured {ht:.2}x, gate 0.95x)"
        );
        if cfg!(not(debug_assertions)) {
            assert_eq!(
                alloc.steady, 0,
                "durable gate: with journal and monitor disarmed the \
                 steady-state engine step must stay allocation-free"
            );
        }
    }

    if let Some(path) = json_path {
        let j = h.to_json(quick, &alloc);
        let text = j.pretty();
        std::fs::write(&path, &text).expect("write bench json");
        // Self-validate: the emitted report must round-trip through the
        // in-repo JSON parser (the CI smoke step relies on this).
        let parsed = Json::parse(&text).expect("BENCH_PR10.json must parse");
        let n = parsed
            .get("entries")
            .and_then(|e| e.as_arr())
            .map(|a| a.len())
            .unwrap_or(0);
        assert_eq!(n, h.entries.len(), "entry count must survive round-trip");
        for (p, s) in [("scheduler-decision", 5000usize), ("digest-sync", 16)] {
            assert!(
                parsed
                    .at(&format!("speedups.{p}@{s}"))
                    .and_then(|v| v.as_f64())
                    .is_some(),
                "gate speedup {p}@{s} missing from report"
            );
        }
        for p in KV_GATE_PATHS {
            for &s in &KV_SIZES {
                assert!(
                    parsed
                        .at(&format!("speedups.{p}@{s}"))
                        .and_then(|v| v.as_f64())
                        .is_some(),
                    "kv gate speedup {p}@{s} missing from report"
                );
            }
        }
        assert!(
            parsed
                .at("speedups.fleet-step@16x4")
                .and_then(|v| v.as_f64())
                .is_some(),
            "fleet-step@16x4 speedup missing from report"
        );
        assert!(
            parsed
                .at("speedups.obs-step@8")
                .and_then(|v| v.as_f64())
                .is_some(),
            "obs gate speedup obs-step@8 missing from report"
        );
        assert!(
            parsed
                .at("speedups.faults-step@8")
                .and_then(|v| v.as_f64())
                .is_some(),
            "faults gate speedup faults-step@8 missing from report"
        );
        assert!(
            parsed
                .at("speedups.slo-tick@4")
                .and_then(|v| v.as_f64())
                .is_some(),
            "slo gate speedup slo-tick@4 missing from report"
        );
        assert!(
            parsed
                .at("engine_step_allocs_steady")
                .and_then(|v| v.as_f64())
                .is_some(),
            "engine-step allocation metric missing from report"
        );
        println!("wrote {path} ({n} entries, validated)");
    }
    if let Some(path) = experiments_path {
        write_experiments(&path, &perf_table(&h));
    }
}

//! `cargo bench --bench microbench` — hot-path micro-benchmarks of the L3
//! coordinator (criterion is unreachable offline; this is a from-scratch
//! timing harness with warmup + median-of-runs). Feeds EXPERIMENTS.md §Perf.
//!
//! Paths measured:
//!   * scheduler decision per iteration at pool sizes 100/1000/5000
//!   * KV manager: allocate/release cycle, prefix lookup, eviction churn
//!   * radix index: insert/best_cached at depth
//!   * estimator: batch_time + fit
//!   * end-to-end sim iterations/second
//!   * PJRT step latency per bucket (if artifacts are built)

use std::collections::VecDeque;
use std::time::Instant;

use echo::config::{SchedulerKind, SystemConfig};
use echo::core::{PromptSpec, Request, RequestStore, TaskClass};
use echo::engine::{sim::SimBackend, Engine};
use echo::estimator::{BatchShape, PrefillItem, TimeModel};
use echo::kvcache::{EvictionPolicy, KvManager};
use echo::scheduler::{OfflinePool, RadixIndex, Scheduler};
use echo::utils::rng::Rng;
use echo::workload::{synthesize, DatasetSpec};

/// Median wall-time per op over `runs` timed batches of `iters_per_run`.
fn bench<F: FnMut()>(name: &str, iters_per_run: usize, runs: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters_per_run.min(100) {
        f();
    }
    let mut samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters_per_run {
                f();
            }
            t0.elapsed().as_secs_f64() / iters_per_run as f64
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    let unit = if med < 1e-6 {
        format!("{:.1} ns", med * 1e9)
    } else if med < 1e-3 {
        format!("{:.2} us", med * 1e6)
    } else {
        format!("{:.3} ms", med * 1e3)
    };
    println!("{name:<56} {unit:>12}/op");
    med
}

fn bench_scheduler_decision(pool_size: usize) {
    let mut cfg = SystemConfig::a100_llama8b();
    cfg.scheduler.kind = SchedulerKind::Echo;
    let block_size = cfg.cache.block_size;
    let mut sched = Scheduler::new(
        cfg.scheduler.clone(),
        cfg.slo,
        TimeModel::new(cfg.time_model),
        block_size,
    );
    let mut store = RequestStore::new();
    let mut queue = VecDeque::new();
    let mut pool = OfflinePool::default_buckets();
    let mut kv = KvManager::new(256, block_size, EvictionPolicy::TaskAware); // tiny memory: admissions fail fast
    let mut rng = Rng::new(1);
    let spec = DatasetSpec::loogle_qa_short();
    let batch = synthesize(&spec, pool_size, TaskClass::Offline, 0.0, &mut store, &mut rng);
    for &id in &batch.ids {
        let r = store.get(id).clone();
        let keys = r.prompt.content_keys(id, r.prompt.total_len, block_size);
        kv.register_future(&keys);
        pool.add(id, r.prompt.total_len, keys);
    }
    // One running online decode so the SLO path is active.
    let online = store.fresh_id();
    let mut r = Request::new(online, TaskClass::Online, 0.0, PromptSpec::sim(100, None), 64);
    r.state = echo::core::ReqState::Running;
    r.phase = echo::core::Phase::Decode;
    r.computed = 100;
    r.generated = 1;
    r.token_times.push(0.0);
    store.insert(r);
    kv.allocate(online, TaskClass::Online, &[], 7, 0.0).unwrap();
    let mut now = 0.0;
    bench(
        &format!("scheduler decision (Echo, pool={pool_size}, memory-tight)"),
        200,
        7,
        || {
            now += 0.01;
            let out = sched.schedule(now, &mut store, &mut queue, &mut pool, &mut kv);
            std::hint::black_box(out.plan.items.len());
        },
    );
}

fn bench_kv_ops() {
    let mut kv = KvManager::new(8192, 16, EvictionPolicy::TaskAware);
    let mut id = 0u64;
    bench("kv allocate+release (32 blocks, keyed)", 500, 7, || {
        id += 1;
        let keys: Vec<u128> = (0..32).map(|i| ((id as u128) << 32) | i).collect();
        kv.allocate(id, TaskClass::Offline, &keys, 32, id as f64).unwrap();
        kv.release(id, true);
    });
    // Prefix lookup on a warm cache.
    let keys: Vec<u128> = (0..512).map(|i| (7u128 << 96) | i).collect();
    kv.flush_cache();
    kv.register_future(&keys);
    id += 1;
    kv.allocate(id, TaskClass::Offline, &keys, 512, 0.0).unwrap();
    kv.release(id, false);
    bench("kv peek_prefix (512 cached blocks)", 2000, 7, || {
        std::hint::black_box(kv.peek_prefix(&keys));
    });
    bench("kv eviction_preview (64 victims)", 2000, 7, || {
        std::hint::black_box(kv.eviction_preview(64));
    });
    // Eviction churn: small cache, rotating working sets.
    let mut kv = KvManager::new(256, 16, EvictionPolicy::TaskAware);
    let mut epoch = 0u64;
    bench("kv eviction churn (alloc 64 into full cache)", 300, 7, || {
        epoch += 1;
        let keys: Vec<u128> = (0..64).map(|i| ((epoch as u128) << 32) | i).collect();
        kv.allocate(epoch, TaskClass::Offline, &keys, 64, epoch as f64).unwrap();
        kv.release(epoch, true);
    });
}

fn bench_radix() {
    let mut idx = RadixIndex::default();
    for r in 0..1000u64 {
        let group = r % 20;
        let keys: Vec<u128> = (0..64)
            .map(|i| if i < 48 { ((group as u128) << 32) | i } else { ((r as u128) << 48) | i })
            .collect();
        idx.insert(r, keys);
    }
    let mut kv = KvManager::new(4096, 16, EvictionPolicy::TaskAware);
    let warm: Vec<u128> = (0..48).map(|i| (3u128 << 32) | i).collect();
    kv.register_future(&warm);
    kv.allocate(1_000_001, TaskClass::Offline, &warm, 48, 0.0).unwrap();
    kv.release(1_000_001, false);
    bench("radix best_cached (1000 reqs, 48-deep warm path)", 1000, 7, || {
        std::hint::black_box(idx.best_cached(&kv));
    });
}

fn bench_estimator() {
    let tm = TimeModel::new(SystemConfig::a100_llama8b().time_model);
    let shape = BatchShape {
        prefills: vec![PrefillItem { chunk: 512, context: 1024 }],
        decode_lens: (0..64).map(|i| 500 + i * 13).collect(),
    };
    bench("estimator batch_time (1 prefill + 64 decodes)", 20_000, 7, || {
        std::hint::black_box(tm.batch_time(&shape));
    });
}

fn bench_sim_iterations() {
    let mut cfg = SystemConfig::a100_llama8b();
    cfg.scheduler.kind = SchedulerKind::Echo;
    let backend = SimBackend::new(TimeModel::new(cfg.time_model), 2, 0.0);
    let mut e = Engine::new(cfg, backend);
    let mut rng = Rng::new(2);
    let mut store = std::mem::take(&mut e.store);
    let batch = synthesize(
        &DatasetSpec::loogle_qa_short(),
        400,
        TaskClass::Offline,
        0.0,
        &mut store,
        &mut rng,
    );
    e.store = store;
    for &id in &batch.ids {
        let r = e.store.get(id).clone();
        let keys = r.prompt.content_keys(id, r.prompt.total_len, e.cfg.cache.block_size);
        e.kv.register_future(&keys);
        e.pool.add(id, r.prompt.total_len, keys);
    }
    for i in 0..500 {
        let id = e.store.fresh_id();
        e.submit_online(Request::new(
            id,
            TaskClass::Online,
            i as f64 * 0.4,
            PromptSpec::sim(300, None),
            32,
        ));
    }
    let t0 = Instant::now();
    let mut iters = 0usize;
    while e.clock < 120.0 {
        if !e.step().unwrap() {
            break;
        }
        iters += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{:<56} {:>9.0} iters/s  ({} iters, {:.2}s wall, {:.0}s simulated)",
        "end-to-end sim engine", iters as f64 / wall, iters, wall, e.clock
    );
}

#[cfg(not(feature = "runtime"))]
fn bench_pjrt() {
    println!("pjrt step: skipped (built without the `runtime` feature)");
}

#[cfg(feature = "runtime")]
fn bench_pjrt() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("pjrt step: skipped (run `make artifacts`)");
        return;
    }
    let mut rt = echo::runtime::ModelRuntime::load(&dir).unwrap();
    for &bucket in &[1usize, 16, 64] {
        let secs = rt.bench_step(bucket, 128, 10).unwrap();
        let toks = rt.manifest.max_batch * bucket;
        println!(
            "{:<56} {:>9.2} ms/step  ({} tokens -> {:.0} tok/s)",
            format!("pjrt step bucket c{bucket} (context 128, all slots)"),
            secs * 1e3,
            toks,
            toks as f64 / secs
        );
    }
}

fn main() {
    println!("== microbench: L3 coordinator hot paths ==\n");
    for pool in [100usize, 1000, 5000] {
        bench_scheduler_decision(pool);
    }
    bench_kv_ops();
    bench_radix();
    bench_estimator();
    bench_sim_iterations();
    bench_pjrt();
}

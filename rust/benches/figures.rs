//! `cargo bench --bench figures` — regenerates every table and figure of
//! the paper's evaluation (§7) plus the DESIGN.md ablations, printing the
//! same rows/series the paper reports and writing the raw data to
//! bench_figures.json.
//!
//! Pass `-- quick` for CI-scale horizons, or a figure name (e.g. `-- fig6`)
//! to run one.

use echo::figures::{self, FigureOpts};
use echo::utils::json::Json;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    // cargo bench passes --bench; ignore flags.
    let wanted: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with('-') && *a != "quick")
        .map(|s| s.as_str())
        .collect();
    let want = |name: &str| wanted.is_empty() || wanted.contains(&name);

    let opts = if quick { FigureOpts::quick() } else { FigureOpts::standard() };
    println!(
        "figures: horizon={}s mean_rate={}/s seed={} (substrate: calibrated \
         A100/LLaMA-8B cost model; see DESIGN.md substitutions)",
        opts.horizon, opts.mean_rate, opts.seed
    );
    let mut out = Json::obj();
    let t_all = std::time::Instant::now();

    if want("table1") {
        let (t, j) = figures::table1(opts.seed);
        println!("{t}");
        out = out.set("table1", j);
    }
    if want("fig2") {
        let (t, j) = figures::fig2(&opts);
        println!("{t}");
        out = out.set("fig2", j);
    }
    if want("fig6") {
        let (t, j) = figures::fig6(&opts)?;
        println!("{t}");
        out = out.set("fig6", j);
    }
    if want("fig7") {
        let (t, j) = figures::fig7(&opts)?;
        println!("{t}");
        out = out.set("fig7", j);
    }
    if want("fig8") {
        let (t, j) = figures::fig8(&opts)?;
        println!("{t}");
        out = out.set("fig8", j);
    }
    if want("fig9") {
        let (t, j) = figures::fig9(&opts)?;
        println!("{t}");
        out = out.set("fig9", j);
    }
    if want("fig10") {
        let (t, j) = figures::fig10(&opts)?;
        println!("{t}");
        out = out.set("fig10", j);
    }
    if want("fig11") {
        let (t, j) = figures::fig11(&opts)?;
        println!("{t}");
        out = out.set("fig11", j);
    }
    if want("ablations") {
        let (t, j) = figures::ablation_cache(&opts)?;
        println!("{t}");
        out = out.set("ablation_cache", j);
        let (t, j) = figures::ablation_budget(&opts)?;
        println!("{t}");
        out = out.set("ablation_budget", j);
    }
    if want("cluster") {
        let (t, j) = figures::fig_cluster(&opts)?;
        println!("{t}");
        out = out.set("cluster", j);
    }

    std::fs::write("bench_figures.json", out.pretty())?;
    println!(
        "\nwrote bench_figures.json ({:.1}s total)",
        t_all.elapsed().as_secs_f64()
    );
    Ok(())
}

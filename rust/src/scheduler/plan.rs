//! Iteration plan: the batch the scheduler hands to the execution backend.

use crate::core::RequestId;
use crate::estimator::BatchShape;

/// Work assigned to one request in this iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkKind {
    /// Process `chunk` prompt tokens (chunked prefill).
    Prefill { chunk: usize },
    /// Generate one token.
    Decode,
}

#[derive(Clone, Copy, Debug)]
pub struct PlanItem {
    pub req: RequestId,
    pub kind: WorkKind,
}

/// The selected batch plus its estimator view.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub items: Vec<PlanItem>,
    pub shape: BatchShape,
    /// Estimated execution time (Eq. 8); 0 if the estimator is disabled.
    pub est_time: f64,
}

impl Plan {
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn n_prefills(&self) -> usize {
        self.items
            .iter()
            .filter(|i| matches!(i.kind, WorkKind::Prefill { .. }))
            .count()
    }

    pub fn n_decodes(&self) -> usize {
        self.items.len() - self.n_prefills()
    }

    pub fn total_tokens(&self) -> usize {
        self.items
            .iter()
            .map(|i| match i.kind {
                WorkKind::Prefill { chunk } => chunk,
                WorkKind::Decode => 1,
            })
            .sum()
    }
}

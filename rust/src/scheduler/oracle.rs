//! Non-incremental reference scheduler (the pre-trial-delta implementation,
//! kept verbatim as an oracle).
//!
//! [`OracleScheduler`] re-collects and sorts the running set from the store
//! every iteration, clones the whole [`BatchShape`] for every candidate
//! trial, and re-hashes prompts into content keys at every use — exactly
//! what `Scheduler` did before the hot-path overhaul. It exists so that
//!
//!   * the equivalence tests can assert the delta path emits bit-identical
//!     [`Plan`]s (same items, same admissions, same `est_time` bits), and
//!   * `benches/microbench.rs` can record the pre-PR cost in the same
//!     `BENCH_PR2.json` it records the incremental path in (the perf gate's
//!     before/after pair comes from one harness run).
//!
//! Do not optimize this module; its value is being the slow, obviously
//! correct baseline.

use std::collections::VecDeque;

use crate::config::{SchedulerConfig, SchedulerKind};
use crate::core::{ReqState, RequestId, RequestStore, Slo, TaskClass};
use crate::estimator::{BatchShape, PrefillItem, TimeModel};
use crate::kvcache::KvManager;

use super::pool::OfflinePool;
use super::{Outcome, PlanItem, WorkKind};
use super::{EPS_TIME, MIN_BUDGET};

/// Clone-trial reference implementation of [`super::Scheduler`].
pub struct OracleScheduler {
    pub cfg: SchedulerConfig,
    pub slo: Slo,
    pub time_model: TimeModel,
    block_size: usize,
    /// Admission (LIFO preemption) order of running offline requests.
    running_offline: Vec<RequestId>,
    /// SLO-guard actuators, mirrored from the incremental scheduler so the
    /// equivalence tests hold with the guard armed.
    offline_cap: usize,
    offline_admit_paused: bool,
}

impl OracleScheduler {
    pub fn new(
        cfg: SchedulerConfig,
        slo: Slo,
        time_model: TimeModel,
        block_size: usize,
    ) -> Self {
        OracleScheduler {
            cfg,
            slo,
            time_model,
            block_size,
            running_offline: Vec::new(),
            offline_cap: usize::MAX,
            offline_admit_paused: false,
        }
    }

    pub fn set_offline_cap(&mut self, cap: usize) {
        self.offline_cap = cap;
    }

    pub fn set_offline_admit_paused(&mut self, paused: bool) {
        self.offline_admit_paused = paused;
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn on_finished(&mut self, id: RequestId) {
        self.running_offline.retain(|&r| r != id);
    }

    pub fn running_offline_count(&self) -> usize {
        self.running_offline.len()
    }

    fn preempt_one_offline(
        &mut self,
        store: &mut RequestStore,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
        out: &mut Outcome,
    ) -> bool {
        let Some(victim) = self.running_offline.pop() else {
            return false;
        };
        let req = store.get_mut(victim);
        req.preempt();
        kv.release(victim, false);
        let keys = req
            .prompt
            .content_keys(victim, req.prompt.total_len, self.block_size);
        pool.add(victim, req.prompt.total_len, keys);
        out.preempted.push(victim);
        true
    }

    fn slo_budget(
        &self,
        now: f64,
        store: &RequestStore,
        online_decodes: &[RequestId],
        online_prefills: &[(RequestId, usize)],
    ) -> f64 {
        let mut budget = f64::INFINITY;
        for &r in online_decodes {
            budget = budget.min(store.get(r).next_token_deadline(&self.slo) - now);
        }
        for &(r, chunk) in online_prefills {
            let req = store.get(r);
            if req.remaining_prefill() <= chunk {
                budget = budget.min(req.arrival + self.slo.ttft - now);
            }
        }
        budget
    }

    /// Build this iteration's plan (clone-trial reference semantics).
    pub fn schedule(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        online_queue: &mut VecDeque<RequestId>,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
    ) -> Outcome {
        let mut out = Outcome::default();

        // ---- 1. partition the carried-over running set ------------------
        let mut running: Vec<RequestId> = store.ids_in_state(ReqState::Running);
        running.sort_unstable();
        let mut online_decodes = Vec::new();
        let mut online_prefills = Vec::new();
        let mut offline_decodes = Vec::new();
        let mut offline_prefills = Vec::new();
        for id in running {
            let r = store.get(id);
            match (r.class, r.in_prefill()) {
                (TaskClass::Online, false) => online_decodes.push(id),
                (TaskClass::Online, true) => online_prefills.push(id),
                (TaskClass::Offline, false) => offline_decodes.push(id),
                (TaskClass::Offline, true) => offline_prefills.push(id),
            }
        }

        // ---- 2. decode block growth -------------------------------------
        for &id in &online_decodes {
            let needed = self.blocks_for(store.get(id).seq_len() + 1);
            while kv.held_blocks(id) < needed {
                let missing = needed - kv.held_blocks(id);
                if kv.grow(id, TaskClass::Online, missing, now) {
                    break;
                }
                if !self.preempt_one_offline(store, pool, kv, &mut out) {
                    break;
                }
            }
        }
        offline_decodes.retain(|&id| {
            if store.get(id).state != ReqState::Running {
                return false;
            }
            let needed = self.blocks_for(store.get(id).seq_len() + 1);
            let held = kv.held_blocks(id);
            if held >= needed {
                return true;
            }
            if kv.grow(id, TaskClass::Offline, needed - held, now) {
                true
            } else {
                let req = store.get_mut(id);
                req.preempt();
                kv.release(id, false);
                let keys = req
                    .prompt
                    .content_keys(id, req.prompt.total_len, self.block_size);
                pool.add(id, req.prompt.total_len, keys);
                self.running_offline.retain(|&r| r != id);
                out.preempted.push(id);
                false
            }
        });

        // ---- 3. online admission (FCFS) ---------------------------------
        while let Some(&head) = online_queue.front() {
            if online_decodes.len() + online_prefills.len() + 1 > self.cfg.max_batch {
                break;
            }
            let (total_blocks, keys, _prompt_len) = {
                let r = store.get(head);
                (
                    self.blocks_for(r.seq_len() + 1),
                    r.prompt.content_keys(head, r.prompt.total_len, self.block_size),
                    r.prompt.total_len,
                )
            };
            let mut admitted = false;
            loop {
                match kv.allocate(head, TaskClass::Online, &keys, total_blocks, now) {
                    Some(ff) => {
                        let r = store.get_mut(head);
                        r.state = ReqState::Running;
                        r.computed = if self.cfg.fast_forward {
                            ff.min(r.seq_len().saturating_sub(1))
                        } else {
                            0
                        };
                        admitted = true;
                        break;
                    }
                    None => {
                        if !self.preempt_one_offline(store, pool, kv, &mut out) {
                            break;
                        }
                    }
                }
            }
            if !admitted {
                break;
            }
            online_queue.pop_front();
            out.admitted_online.push(head);
            if store.get(head).in_prefill() {
                online_prefills.push(head);
            } else {
                online_decodes.push(head);
            }
        }

        offline_decodes.retain(|&id| store.get(id).state == ReqState::Running);
        offline_prefills.retain(|&id| store.get(id).state == ReqState::Running);

        // ---- 4. mandatory online items ----------------------------------
        let mut shape = BatchShape::default();
        let mut items = Vec::new();
        let mut token_budget = self.cfg.max_batched_tokens;
        let mut offline_budget = self.offline_cap;

        for &id in &online_decodes {
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Decode,
            });
            shape.decode_lens.push(store.get(id).seq_len());
            token_budget = token_budget.saturating_sub(1);
        }
        online_prefills.sort_by_key(|&id| {
            let r = store.get(id);
            (r.arrival as u64, id)
        });
        let mut online_prefill_chunks = Vec::new();
        for &id in &online_prefills {
            if token_budget == 0 {
                break;
            }
            let r = store.get(id);
            let chunk = r.remaining_prefill().min(self.cfg.chunk).min(token_budget);
            if chunk == 0 {
                continue;
            }
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Prefill { chunk },
            });
            shape.prefills.push(PrefillItem {
                chunk,
                context: r.computed,
            });
            token_budget -= chunk;
            online_prefill_chunks.push((id, chunk));
        }

        let budget = if self.cfg.kind.uses_estimator() {
            self.slo_budget(now, store, &online_decodes, &online_prefill_chunks)
        } else {
            f64::INFINITY
        };

        // ---- 5. offline resident decodes --------------------------------
        let mut slots_left = self.cfg.max_batch.saturating_sub(items.len());
        for &id in &offline_decodes {
            if slots_left == 0 || token_budget == 0 || offline_budget == 0 {
                break;
            }
            let len = store.get(id).seq_len();
            let mut trial = shape.clone();
            trial.decode_lens.push(len);
            if self.cfg.kind.uses_estimator()
                && self.time_model.batch_time(&trial) > budget
            {
                out.skipped_offline += 1;
                continue;
            }
            shape = trial;
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Decode,
            });
            token_budget -= 1;
            offline_budget = offline_budget.saturating_sub(1);
            slots_left -= 1;
        }

        // ---- 6. continue running offline prefills -----------------------
        for &id in &offline_prefills {
            if slots_left == 0 || token_budget == 0 || offline_budget == 0 {
                break;
            }
            let r = store.get(id);
            let chunk = r
                .remaining_prefill()
                .min(self.cfg.chunk)
                .min(token_budget)
                .min(offline_budget);
            if chunk == 0 {
                continue;
            }
            let mut trial = shape.clone();
            trial.prefills.push(PrefillItem {
                chunk,
                context: r.computed,
            });
            if self.cfg.kind.uses_estimator()
                && self.time_model.batch_time(&trial) > budget
            {
                out.skipped_offline += 1;
                continue;
            }
            shape = trial;
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Prefill { chunk },
            });
            token_budget -= chunk;
            offline_budget -= chunk;
            slots_left -= 1;
        }

        // ---- 7. new offline admissions ----------------------------------
        if budget > MIN_BUDGET && !self.offline_admit_paused {
            match self.cfg.kind {
                SchedulerKind::Bs | SchedulerKind::BsE => self.admit_fcfs(
                    now,
                    store,
                    pool,
                    kv,
                    &mut items,
                    &mut shape,
                    &mut token_budget,
                    &mut offline_budget,
                    &mut slots_left,
                    budget,
                    &mut out,
                ),
                SchedulerKind::BsES | SchedulerKind::Echo => self.admit_kv_aware(
                    now,
                    store,
                    pool,
                    kv,
                    &mut items,
                    &mut shape,
                    &mut token_budget,
                    &mut offline_budget,
                    &mut slots_left,
                    budget,
                    &mut out,
                ),
            }
        }

        let est_time = if self.cfg.kind.uses_estimator() {
            self.time_model.batch_time(&shape)
        } else {
            0.0
        };
        out.plan = super::Plan {
            items,
            shape,
            est_time,
        };
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn admit_fcfs(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
        items: &mut Vec<PlanItem>,
        shape: &mut BatchShape,
        token_budget: &mut usize,
        offline_budget: &mut usize,
        slots_left: &mut usize,
        budget: f64,
        out: &mut Outcome,
    ) {
        while *slots_left > 0 && *token_budget > 0 && *offline_budget > 0 {
            let Some(head) = pool.fcfs_head() else { break };
            let (prompt_len, seq_len, keys) = {
                let r = store.get(head);
                (
                    r.prompt.total_len,
                    r.seq_len(),
                    r.prompt.content_keys(head, r.prompt.total_len, self.block_size),
                )
            };
            let total_blocks = self.blocks_for(seq_len + 1);
            let hit_blocks = kv.peek_prefix(&keys[..keys.len().min(total_blocks)]);
            let ff = if self.cfg.fast_forward {
                (hit_blocks * self.block_size).min(seq_len - 1)
            } else {
                0
            };
            let chunk = (seq_len - ff)
                .min(self.cfg.chunk)
                .min(*token_budget)
                .min(*offline_budget);
            let mut trial = shape.clone();
            if chunk > 0 {
                trial.prefills.push(PrefillItem {
                    chunk,
                    context: ff,
                });
            } else {
                trial.decode_lens.push(seq_len);
            }
            if self.cfg.kind.uses_estimator() && self.time_model.batch_time(&trial) > budget
            {
                break;
            }
            if kv
                .allocate(head, TaskClass::Offline, &keys, total_blocks, now)
                .is_none()
            {
                break;
            }
            pool.remove(head, prompt_len);
            let r = store.get_mut(head);
            r.state = ReqState::Running;
            r.computed = ff;
            self.running_offline.push(head);
            out.admitted_offline.push(head);
            *shape = trial;
            if chunk > 0 {
                items.push(PlanItem {
                    req: head,
                    kind: WorkKind::Prefill { chunk },
                });
                *token_budget -= chunk;
                *offline_budget -= chunk;
            } else {
                items.push(PlanItem {
                    req: head,
                    kind: WorkKind::Decode,
                });
                *token_budget -= 1;
                *offline_budget = offline_budget.saturating_sub(1);
            }
            *slots_left -= 1;
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn admit_kv_aware(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
        items: &mut Vec<PlanItem>,
        shape: &mut BatchShape,
        token_budget: &mut usize,
        offline_budget: &mut usize,
        slots_left: &mut usize,
        budget: f64,
        out: &mut Outcome,
    ) {
        while *slots_left > 0 && *token_budget > 0 && *offline_budget > 0 {
            let candidates = pool.candidates(kv, self.cfg.mutation_budget);
            if candidates.is_empty() {
                break;
            }
            let base_time = self.time_model.batch_time(shape);
            // One availability snapshot per admission round (shared by all
            // candidate trials), mirroring the incremental scheduler.
            let avail = kv.availability();
            let mut best: Option<(f64, RequestId, usize, usize, BatchShape)> = None;
            for id in candidates {
                let r = store.get(id);
                let prompt_len = r.prompt.total_len;
                let seq_len = r.seq_len();
                let keys = r.prompt.content_keys(id, prompt_len, self.block_size);
                let total_blocks = self.blocks_for(seq_len + 1);
                let hit_blocks = kv.peek_prefix(&keys[..keys.len().min(total_blocks)]);
                let ff = if self.cfg.fast_forward {
                    (hit_blocks * self.block_size).min(seq_len - 1)
                } else {
                    0
                };
                let fresh = total_blocks - hit_blocks;
                if fresh > avail.for_offline() {
                    continue;
                }
                let chunk = (seq_len - ff)
                    .min(self.cfg.chunk)
                    .min(*token_budget)
                    .min(*offline_budget);
                let mut trial = shape.clone();
                if chunk > 0 {
                    trial.prefills.push(PrefillItem {
                        chunk,
                        context: ff,
                    });
                } else {
                    trial.decode_lens.push(seq_len);
                }
                let t = self.time_model.batch_time(&trial);
                if t > budget {
                    continue;
                }
                let need_evict = fresh.saturating_sub(avail.free);
                let punish = kv.eviction_preview(need_evict) as f64;
                let benefit = (ff + chunk.max(1)) as f64;
                let dt = (t - base_time).max(EPS_TIME);
                let score = (benefit - punish) / dt;
                if score <= 0.0 {
                    continue;
                }
                if best.as_ref().map_or(true, |b| score > b.0) {
                    best = Some((score, id, ff, chunk, trial));
                }
            }
            let Some((_, id, ff, chunk, trial)) = best else { break };
            let (prompt_len, keys, total_blocks) = {
                let r = store.get(id);
                (
                    r.prompt.total_len,
                    r.prompt.content_keys(id, r.prompt.total_len, self.block_size),
                    self.blocks_for(r.seq_len() + 1),
                )
            };
            if kv
                .allocate(id, TaskClass::Offline, &keys, total_blocks, now)
                .is_none()
            {
                break;
            }
            pool.remove(id, prompt_len);
            let r = store.get_mut(id);
            r.state = ReqState::Running;
            r.computed = ff;
            self.running_offline.push(id);
            out.admitted_offline.push(id);
            *shape = trial;
            if chunk > 0 {
                items.push(PlanItem {
                    req: id,
                    kind: WorkKind::Prefill { chunk },
                });
                *token_budget -= chunk;
                *offline_budget -= chunk;
            } else {
                items.push(PlanItem {
                    req: id,
                    kind: WorkKind::Decode,
                });
                *token_budget -= 1;
                *offline_budget = offline_budget.saturating_sub(1);
            }
            *slots_left -= 1;
        }
    }
}

//! Offline request pool (paper §6, "Online queue and offline pool").
//!
//! Offline requests are bucketed by prompt-length range; inside each bucket
//! a radix tree over content-key sequences groups requests by shared
//! prefix. The scheduler asks for *candidates*: per bucket, the FCFS head
//! plus the head of the prefix group whose cached prefix is longest right
//! now — which is exactly the "reorganize for spatial locality" trick the
//! paper credits for the cache-hit gains (§7.3), with a search budget far
//! below trying the whole pool.

use crate::core::RequestId;
use crate::kvcache::KvManager;
use crate::utils::hash::FxHashMap;

/// Arena node index (`u32`: a pool radix tree holds at most one node per
/// registered block key, far below 4 billion).
type NodeIdx = u32;

/// Radix tree over block content-key sequences. Each node = one block key;
/// requests register their full key path; lookup walks the cached prefix.
///
/// Layout: nodes live in one arena `Vec` and refer to children by index —
/// no per-node heap boxes to chase, and freed nodes are recycled through a
/// free list. Each node's children are a `Vec<(key, child)>` kept sorted by
/// key: binary-search lookup, and in-order iteration preserves the exact
/// deterministic candidate order the old `BTreeMap` tree had. Removal is
/// iterative (walk down recording the trail, prune empty nodes on the way
/// back up) — no recursion, no stack depth proportional to prompt length.
pub struct RadixIndex {
    nodes: Vec<Node>,
    /// Recycled arena slots.
    free: Vec<NodeIdx>,
    paths: FxHashMap<RequestId, Vec<u128>>,
}

const ROOT: NodeIdx = 0;

#[derive(Default)]
struct Node {
    /// (block key, child index), sorted ascending by key.
    children: Vec<(u128, NodeIdx)>,
    /// Requests whose key path ends at this node (leaf registration only,
    /// to bound memory).
    requests: Vec<RequestId>,
}

impl Default for RadixIndex {
    fn default() -> Self {
        RadixIndex {
            nodes: vec![Node::default()], // slot 0 = root, never freed
            free: Vec::new(),
            paths: FxHashMap::default(),
        }
    }
}

impl RadixIndex {
    fn alloc_node(&mut self) -> NodeIdx {
        if let Some(i) = self.free.pop() {
            i
        } else {
            self.nodes.push(Node::default());
            (self.nodes.len() - 1) as NodeIdx
        }
    }

    fn child_of(&self, node: NodeIdx, key: u128) -> Result<usize, usize> {
        self.nodes[node as usize]
            .children
            .binary_search_by_key(&key, |c| c.0)
    }

    pub fn insert(&mut self, id: RequestId, keys: Vec<u128>) {
        let mut cur = ROOT;
        for &k in &keys {
            cur = match self.child_of(cur, k) {
                Ok(pos) => self.nodes[cur as usize].children[pos].1,
                Err(pos) => {
                    let child = self.alloc_node();
                    self.nodes[cur as usize].children.insert(pos, (k, child));
                    child
                }
            };
        }
        self.nodes[cur as usize].requests.push(id);
        self.paths.insert(id, keys);
    }

    pub fn remove(&mut self, id: RequestId) {
        let Some(keys) = self.paths.remove(&id) else {
            return;
        };
        // Walk down, recording (parent, child position) per step.
        let mut trail: Vec<(NodeIdx, usize)> = Vec::with_capacity(keys.len());
        let mut cur = ROOT;
        for &k in &keys {
            match self.child_of(cur, k) {
                Ok(pos) => {
                    trail.push((cur, pos));
                    cur = self.nodes[cur as usize].children[pos].1;
                }
                Err(_) => return, // defensive: path not present
            }
        }
        self.nodes[cur as usize].requests.retain(|&r| r != id);
        // Unwind: prune now-empty nodes bottom-up. Positions recorded on
        // the way down stay valid — only deeper nodes were touched since.
        let mut child = cur;
        while let Some((parent, pos)) = trail.pop() {
            let n = &self.nodes[child as usize];
            if !n.children.is_empty() || !n.requests.is_empty() {
                break;
            }
            self.nodes[parent as usize].children.remove(pos);
            self.nodes[child as usize] = Node::default();
            self.free.push(child);
            child = parent;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    pub fn len(&self) -> usize {
        self.paths.len()
    }

    /// Walk as deep as the KV manager has the path cached and return the
    /// request reachable from the deepest cached node plus the depth
    /// (cached blocks usable by that request).
    pub fn best_cached(&self, kv: &KvManager) -> Option<(RequestId, usize)> {
        let mut cur = ROOT;
        let mut depth = 0usize;
        loop {
            let mut advanced = false;
            for &(k, child) in &self.nodes[cur as usize].children {
                if kv.peek_prefix(&[k]) == 1 {
                    cur = child;
                    depth += 1;
                    advanced = true;
                    break;
                }
            }
            if !advanced {
                break;
            }
        }
        if depth == 0 {
            return None;
        }
        self.any_request(cur).map(|id| (id, depth))
    }

    /// First request in deterministic preorder (children in key order)
    /// reachable from `start` — iterative DFS over the arena.
    fn any_request(&self, start: NodeIdx) -> Option<RequestId> {
        let mut stack = vec![start];
        while let Some(n) = stack.pop() {
            let node = &self.nodes[n as usize];
            if let Some(&id) = node.requests.first() {
                return Some(id);
            }
            for &(_, child) in node.children.iter().rev() {
                stack.push(child);
            }
        }
        None
    }

    /// Arena occupancy `(live_nodes, capacity)` — test/bench introspection.
    #[doc(hidden)]
    pub fn arena_stats(&self) -> (usize, usize) {
        (self.nodes.len() - self.free.len(), self.nodes.len())
    }
}

struct Bucket {
    /// Inclusive upper prompt-length bound of this bucket.
    max_len: usize,
    /// FCFS order within the bucket.
    fifo: Vec<RequestId>,
    index: RadixIndex,
}

/// Pool of pending offline requests (not currently running).
pub struct OfflinePool {
    buckets: Vec<Bucket>,
    len: usize,
}

impl OfflinePool {
    /// `bounds`: ascending bucket upper bounds; a catch-all bucket is
    /// appended automatically.
    pub fn new(bounds: &[usize]) -> Self {
        let mut buckets: Vec<Bucket> = bounds
            .iter()
            .map(|&b| Bucket {
                max_len: b,
                fifo: Vec::new(),
                index: RadixIndex::default(),
            })
            .collect();
        buckets.push(Bucket {
            max_len: usize::MAX,
            fifo: Vec::new(),
            index: RadixIndex::default(),
        });
        OfflinePool { buckets, len: 0 }
    }

    /// Default bucket bounds for the paper's workloads (short chat /
    /// medium tool / long document prompts).
    pub fn default_buckets() -> Self {
        Self::new(&[512, 2048, 8192])
    }

    fn bucket_mut(&mut self, prompt_len: usize) -> &mut Bucket {
        let i = self
            .buckets
            .iter()
            .position(|b| prompt_len <= b.max_len)
            // lint: allow-unwrap(the last bucket's max_len is usize::MAX)
            .expect("catch-all bucket");
        &mut self.buckets[i]
    }

    /// Add a pending offline request with its content-key path.
    pub fn add(&mut self, id: RequestId, prompt_len: usize, keys: Vec<u128>) {
        let b = self.bucket_mut(prompt_len);
        b.fifo.push(id);
        b.index.insert(id, keys);
        self.len += 1;
    }

    /// Remove (scheduled or cancelled).
    pub fn remove(&mut self, id: RequestId, prompt_len: usize) {
        let b = self.bucket_mut(prompt_len);
        if let Some(pos) = b.fifo.iter().position(|&r| r == id) {
            b.fifo.remove(pos);
            b.index.remove(id);
            self.len -= 1;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Newest `n` pending ids across all buckets (id order == submission
    /// order). These are the cheapest victims for cluster work-stealing:
    /// taking the tail preserves FCFS fairness for the head of the pool.
    pub fn steal_candidates(&self, n: usize) -> Vec<RequestId> {
        let mut all: Vec<RequestId> = self
            .buckets
            .iter()
            .flat_map(|b| b.fifo.iter().copied())
            .collect();
        all.sort_unstable();
        all.split_off(all.len().saturating_sub(n))
    }

    /// Global FCFS head (the BS / BS+E policies).
    pub fn fcfs_head(&self) -> Option<RequestId> {
        // Oldest insertion across buckets: compare by id (monotonic).
        self.buckets
            .iter()
            .filter_map(|b| b.fifo.first().copied())
            .min()
    }

    /// Candidate set for the KV-aware plan generator: per bucket the FCFS
    /// head + the request with the deepest currently-cached prefix, capped
    /// at `budget` total.
    pub fn candidates(&self, kv: &KvManager, budget: usize) -> Vec<RequestId> {
        let mut out = Vec::new();
        for b in &self.buckets {
            if let Some((id, _depth)) = b.index.best_cached(kv) {
                if !out.contains(&id) {
                    out.push(id);
                }
            }
            if let Some(&head) = b.fifo.first() {
                if !out.contains(&head) {
                    out.push(head);
                }
            }
            // A couple of FCFS followers widen the search cheaply.
            for &id in b.fifo.iter().skip(1).take(2) {
                if !out.contains(&id) {
                    out.push(id);
                }
            }
            if out.len() >= budget {
                out.truncate(budget);
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskClass;
    use crate::kvcache::{EvictionPolicy, KvManager};

    fn kv() -> KvManager {
        KvManager::new(64, 16, EvictionPolicy::TaskAware)
    }

    fn keyseq(tag: u128, n: usize) -> Vec<u128> {
        (0..n).map(|i| (tag << 32) | i as u128).collect()
    }

    #[test]
    fn radix_insert_remove() {
        let mut idx = RadixIndex::default();
        idx.insert(1, keyseq(10, 3));
        idx.insert(2, keyseq(10, 5)); // shares 3-block prefix
        idx.insert(3, keyseq(20, 2));
        assert_eq!(idx.len(), 3);
        idx.remove(2);
        assert_eq!(idx.len(), 2);
        idx.remove(1);
        idx.remove(3);
        assert!(idx.is_empty());
        assert!(
            idx.nodes[ROOT as usize].children.is_empty(),
            "tree must prune empty paths"
        );
        let (live, _) = idx.arena_stats();
        assert_eq!(live, 1, "only the root survives a full drain");
    }

    #[test]
    fn arena_recycles_freed_nodes() {
        let mut idx = RadixIndex::default();
        idx.insert(1, keyseq(1, 8));
        let (_, cap_before) = idx.arena_stats();
        idx.remove(1);
        // Re-inserting an equally deep path must reuse the freed slots.
        idx.insert(2, keyseq(2, 8));
        let (live, cap_after) = idx.arena_stats();
        assert_eq!(cap_after, cap_before, "freed nodes must be recycled");
        assert_eq!(live, 9); // root + 8 path nodes
        // And lookups still walk the recycled path.
        let mut m = kv();
        let cached = keyseq(2, 3);
        m.register_future(&cached);
        m.allocate(77, TaskClass::Offline, &cached, 3, 0.0).unwrap();
        m.release(77, false);
        let (id, depth) = idx.best_cached(&m).unwrap();
        assert_eq!((id, depth), (2, 3));
    }

    #[test]
    fn best_cached_follows_cache_state() {
        let mut idx = RadixIndex::default();
        idx.insert(1, keyseq(10, 4));
        idx.insert(2, keyseq(20, 4));
        let mut m = kv();
        assert!(idx.best_cached(&m).is_none());
        // Cache 2 blocks of group 20's path.
        let cached = keyseq(20, 2);
        m.register_future(&cached);
        m.allocate(99, TaskClass::Offline, &cached, 2, 0.0).unwrap();
        m.release(99, false);
        let (id, depth) = idx.best_cached(&m).unwrap();
        assert_eq!(id, 2);
        assert_eq!(depth, 2);
    }

    #[test]
    fn pool_buckets_and_fcfs() {
        let mut p = OfflinePool::new(&[100, 1000]);
        p.add(5, 50, keyseq(1, 3));
        p.add(6, 500, keyseq(2, 30));
        p.add(7, 5000, keyseq(3, 300));
        assert_eq!(p.len(), 3);
        assert_eq!(p.fcfs_head(), Some(5));
        p.remove(5, 50);
        assert_eq!(p.fcfs_head(), Some(6));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn candidates_prefer_cached_groups() {
        let mut p = OfflinePool::new(&[100]);
        // Two requests in the same bucket, different groups.
        p.add(1, 50, keyseq(10, 3));
        p.add(2, 50, keyseq(20, 3));
        let mut m = kv();
        let cached = keyseq(20, 3);
        m.register_future(&cached);
        m.allocate(99, TaskClass::Offline, &cached, 3, 0.0).unwrap();
        m.release(99, false);
        let c = p.candidates(&m, 8);
        assert!(c.contains(&2), "cached-prefix request must be a candidate");
        assert!(c.contains(&1), "FCFS head must be a candidate");
        assert_eq!(c[0], 2, "cached candidate ranks first");
    }

    #[test]
    fn candidates_respect_budget() {
        let mut p = OfflinePool::new(&[]);
        for i in 0..20 {
            p.add(i, 10, keyseq(i as u128, 2));
        }
        let m = kv();
        assert!(p.candidates(&m, 3).len() <= 3);
    }
}

//! Offline request pool (paper §6, "Online queue and offline pool").
//!
//! Offline requests are bucketed by prompt-length range; inside each bucket
//! a radix tree over content-key sequences groups requests by shared
//! prefix. The scheduler asks for *candidates*: per bucket, the FCFS head
//! plus the head of the prefix group whose cached prefix is longest right
//! now — which is exactly the "reorganize for spatial locality" trick the
//! paper credits for the cache-hit gains (§7.3), with a search budget far
//! below trying the whole pool.

use std::collections::{BTreeMap, HashMap};

use crate::core::RequestId;
use crate::kvcache::KvManager;

/// Radix tree over block content-key sequences. Each node = one block key;
/// requests register their full key path; lookup walks the cached prefix.
#[derive(Default)]
pub struct RadixIndex {
    root: Node,
    paths: HashMap<RequestId, Vec<u128>>,
}

#[derive(Default)]
struct Node {
    // BTreeMap: deterministic iteration order (candidate selection must be
    // reproducible across runs).
    children: BTreeMap<u128, Node>,
    /// Requests whose key path ends at or passes through this node, kept
    /// only at the *leaf* (full path) to bound memory.
    requests: Vec<RequestId>,
}

impl RadixIndex {
    pub fn insert(&mut self, id: RequestId, keys: Vec<u128>) {
        let mut node = &mut self.root;
        for &k in &keys {
            node = node.children.entry(k).or_default();
        }
        node.requests.push(id);
        self.paths.insert(id, keys);
    }

    pub fn remove(&mut self, id: RequestId) {
        let Some(keys) = self.paths.remove(&id) else {
            return;
        };
        Self::remove_rec(&mut self.root, &keys, id);
    }

    fn remove_rec(node: &mut Node, keys: &[u128], id: RequestId) -> bool {
        match keys.split_first() {
            None => {
                node.requests.retain(|&r| r != id);
            }
            Some((&k, rest)) => {
                if let Some(child) = node.children.get_mut(&k) {
                    if Self::remove_rec(child, rest, id) {
                        node.children.remove(&k);
                    }
                }
            }
        }
        node.children.is_empty() && node.requests.is_empty()
    }

    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    pub fn len(&self) -> usize {
        self.paths.len()
    }

    /// Walk as deep as the KV manager has the path cached and return the
    /// request reachable from the deepest cached node plus the depth
    /// (cached blocks usable by that request).
    pub fn best_cached(&self, kv: &KvManager) -> Option<(RequestId, usize)> {
        let mut node = &self.root;
        let mut depth = 0usize;
        loop {
            let mut advanced = false;
            for (&k, child) in &node.children {
                if kv.peek_prefix(&[k]) == 1 {
                    node = child;
                    depth += 1;
                    advanced = true;
                    break;
                }
            }
            if !advanced {
                break;
            }
        }
        if depth == 0 {
            return None;
        }
        Self::any_request(node).map(|id| (id, depth))
    }

    fn any_request(node: &Node) -> Option<RequestId> {
        if let Some(&id) = node.requests.first() {
            return Some(id);
        }
        node.children.values().find_map(Self::any_request)
    }
}

struct Bucket {
    /// Inclusive upper prompt-length bound of this bucket.
    max_len: usize,
    /// FCFS order within the bucket.
    fifo: Vec<RequestId>,
    index: RadixIndex,
}

/// Pool of pending offline requests (not currently running).
pub struct OfflinePool {
    buckets: Vec<Bucket>,
    len: usize,
}

impl OfflinePool {
    /// `bounds`: ascending bucket upper bounds; a catch-all bucket is
    /// appended automatically.
    pub fn new(bounds: &[usize]) -> Self {
        let mut buckets: Vec<Bucket> = bounds
            .iter()
            .map(|&b| Bucket {
                max_len: b,
                fifo: Vec::new(),
                index: RadixIndex::default(),
            })
            .collect();
        buckets.push(Bucket {
            max_len: usize::MAX,
            fifo: Vec::new(),
            index: RadixIndex::default(),
        });
        OfflinePool { buckets, len: 0 }
    }

    /// Default bucket bounds for the paper's workloads (short chat /
    /// medium tool / long document prompts).
    pub fn default_buckets() -> Self {
        Self::new(&[512, 2048, 8192])
    }

    fn bucket_mut(&mut self, prompt_len: usize) -> &mut Bucket {
        let i = self
            .buckets
            .iter()
            .position(|b| prompt_len <= b.max_len)
            .expect("catch-all bucket");
        &mut self.buckets[i]
    }

    /// Add a pending offline request with its content-key path.
    pub fn add(&mut self, id: RequestId, prompt_len: usize, keys: Vec<u128>) {
        let b = self.bucket_mut(prompt_len);
        b.fifo.push(id);
        b.index.insert(id, keys);
        self.len += 1;
    }

    /// Remove (scheduled or cancelled).
    pub fn remove(&mut self, id: RequestId, prompt_len: usize) {
        let b = self.bucket_mut(prompt_len);
        if let Some(pos) = b.fifo.iter().position(|&r| r == id) {
            b.fifo.remove(pos);
            b.index.remove(id);
            self.len -= 1;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Newest `n` pending ids across all buckets (id order == submission
    /// order). These are the cheapest victims for cluster work-stealing:
    /// taking the tail preserves FCFS fairness for the head of the pool.
    pub fn steal_candidates(&self, n: usize) -> Vec<RequestId> {
        let mut all: Vec<RequestId> = self
            .buckets
            .iter()
            .flat_map(|b| b.fifo.iter().copied())
            .collect();
        all.sort_unstable();
        all.split_off(all.len().saturating_sub(n))
    }

    /// Global FCFS head (the BS / BS+E policies).
    pub fn fcfs_head(&self) -> Option<RequestId> {
        // Oldest insertion across buckets: compare by id (monotonic).
        self.buckets
            .iter()
            .filter_map(|b| b.fifo.first().copied())
            .min()
    }

    /// Candidate set for the KV-aware plan generator: per bucket the FCFS
    /// head + the request with the deepest currently-cached prefix, capped
    /// at `budget` total.
    pub fn candidates(&self, kv: &KvManager, budget: usize) -> Vec<RequestId> {
        let mut out = Vec::new();
        for b in &self.buckets {
            if let Some((id, _depth)) = b.index.best_cached(kv) {
                if !out.contains(&id) {
                    out.push(id);
                }
            }
            if let Some(&head) = b.fifo.first() {
                if !out.contains(&head) {
                    out.push(head);
                }
            }
            // A couple of FCFS followers widen the search cheaply.
            for &id in b.fifo.iter().skip(1).take(2) {
                if !out.contains(&id) {
                    out.push(id);
                }
            }
            if out.len() >= budget {
                out.truncate(budget);
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskClass;
    use crate::kvcache::{EvictionPolicy, KvManager};

    fn kv() -> KvManager {
        KvManager::new(64, 16, EvictionPolicy::TaskAware)
    }

    fn keyseq(tag: u128, n: usize) -> Vec<u128> {
        (0..n).map(|i| (tag << 32) | i as u128).collect()
    }

    #[test]
    fn radix_insert_remove() {
        let mut idx = RadixIndex::default();
        idx.insert(1, keyseq(10, 3));
        idx.insert(2, keyseq(10, 5)); // shares 3-block prefix
        idx.insert(3, keyseq(20, 2));
        assert_eq!(idx.len(), 3);
        idx.remove(2);
        assert_eq!(idx.len(), 2);
        idx.remove(1);
        idx.remove(3);
        assert!(idx.is_empty());
        assert!(idx.root.children.is_empty(), "tree must prune empty paths");
    }

    #[test]
    fn best_cached_follows_cache_state() {
        let mut idx = RadixIndex::default();
        idx.insert(1, keyseq(10, 4));
        idx.insert(2, keyseq(20, 4));
        let mut m = kv();
        assert!(idx.best_cached(&m).is_none());
        // Cache 2 blocks of group 20's path.
        let cached = keyseq(20, 2);
        m.register_future(&cached);
        m.allocate(99, TaskClass::Offline, &cached, 2, 0.0).unwrap();
        m.release(99, false);
        let (id, depth) = idx.best_cached(&m).unwrap();
        assert_eq!(id, 2);
        assert_eq!(depth, 2);
    }

    #[test]
    fn pool_buckets_and_fcfs() {
        let mut p = OfflinePool::new(&[100, 1000]);
        p.add(5, 50, keyseq(1, 3));
        p.add(6, 500, keyseq(2, 30));
        p.add(7, 5000, keyseq(3, 300));
        assert_eq!(p.len(), 3);
        assert_eq!(p.fcfs_head(), Some(5));
        p.remove(5, 50);
        assert_eq!(p.fcfs_head(), Some(6));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn candidates_prefer_cached_groups() {
        let mut p = OfflinePool::new(&[100]);
        // Two requests in the same bucket, different groups.
        p.add(1, 50, keyseq(10, 3));
        p.add(2, 50, keyseq(20, 3));
        let mut m = kv();
        let cached = keyseq(20, 3);
        m.register_future(&cached);
        m.allocate(99, TaskClass::Offline, &cached, 3, 0.0).unwrap();
        m.release(99, false);
        let c = p.candidates(&m, 8);
        assert!(c.contains(&2), "cached-prefix request must be a candidate");
        assert!(c.contains(&1), "FCFS head must be a candidate");
        assert_eq!(c[0], 2, "cached candidate ranks first");
    }

    #[test]
    fn candidates_respect_budget() {
        let mut p = OfflinePool::new(&[]);
        for i in 0..20 {
            p.add(i, 10, keyseq(i as u128, 2));
        }
        let m = kv();
        assert!(p.candidates(&m, 3).len() <= 3);
    }
}

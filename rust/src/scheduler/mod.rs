//! KV-cache-aware task scheduler (paper §4.1).
//!
//! Per iteration the scheduler builds a batch (a [`Plan`]) out of
//!   * all running online decodes (always scheduled, FCFS admission),
//!   * online prefill chunks (FCFS, chunked prefill),
//!   * offline work selected by the strategy under SLO + memory constraints.
//!
//! The search-space reduction is the paper's "last batch" observation: the
//! batch starts from the previous iteration's running set minus completions
//! and only *mutations* are considered — preempt an offline request for
//! memory, admit an offline prefill (preferring candidates whose prefix is
//! cached), continue an offline decode whose KV is resident. Candidates are
//! scored by Eq. 4, `(Benefit − Punishment) / Time`.
//!
//! Strategy ladder (§7.1): BS (priority preemption, no estimator), BS+E
//! (+SLO-constrained admission), BS+E+S (+KV-aware selection), Echo
//! (+task-aware cache manager, configured at the KvManager level).

pub mod oracle;
pub mod plan;
pub mod pool;

pub use oracle::OracleScheduler;
pub use plan::{Plan, PlanItem, WorkKind};
pub use pool::{OfflinePool, RadixIndex};

use std::collections::VecDeque;

use crate::config::{SchedulerConfig, SchedulerKind};
use crate::core::{ReqState, RequestId, RequestStore, Slo, TaskClass};
use crate::estimator::{PrefillItem, TimeModel, TrialShape};
use crate::kvcache::KvManager;

/// What the scheduler decided beyond the plan itself.
#[derive(Debug, Default)]
pub struct Outcome {
    pub plan: Plan,
    pub admitted_online: Vec<RequestId>,
    pub admitted_offline: Vec<RequestId>,
    pub preempted: Vec<RequestId>,
    /// Offline decodes left idle this iteration to honor the SLO.
    pub skipped_offline: usize,
    /// Estimator shape evaluations performed while building this plan
    /// (admission trials + SLO-budget probes); 0 when the estimator is off.
    pub trials: usize,
}

pub struct Scheduler {
    pub cfg: SchedulerConfig,
    pub slo: Slo,
    pub time_model: TimeModel,
    block_size: usize,
    /// Admission (LIFO preemption) order of running offline requests.
    running_offline: Vec<RequestId>,
    /// All running request ids, kept sorted ascending across iterations —
    /// the paper's "last batch" carry-over, maintained incrementally at the
    /// admission/preemption/completion transitions instead of re-collected
    /// and re-sorted from the store every iteration.
    running: Vec<RequestId>,
    /// SLO-guard actuator (PR 9): offline tokens-per-batch cap. The
    /// `usize::MAX` sentinel means "unguarded" and keeps the off path to a
    /// single never-taken comparison per offline item — no branch on an
    /// `Option`, no allocation.
    offline_cap: usize,
    /// SLO-guard actuator (PR 9): when set, phases 5/6 still run resident
    /// offline work (drain) unless the cap is 0, but phase 7 admits no new
    /// offline requests from the pool.
    offline_admit_paused: bool,
    /// Reusable partition buffers for [`Scheduler::schedule_into`]: cleared
    /// and refilled in place each iteration, so the steady-state decision
    /// makes no heap allocation (see `Engine::step_alloc_growth`).
    scratch: SchedScratch,
}

/// Per-iteration partition scratch (taken out of `self` during a schedule
/// call so the borrow checker allows `&mut self` helper calls, then put
/// back with its capacity).
#[derive(Default)]
struct SchedScratch {
    online_decodes: Vec<RequestId>,
    online_prefills: Vec<RequestId>,
    offline_decodes: Vec<RequestId>,
    offline_prefills: Vec<RequestId>,
    online_prefill_chunks: Vec<(RequestId, usize)>,
    /// Capacity-growth events on the scratch buffers (regression hook:
    /// flat across steady-state iterations).
    grows: u64,
}

/// Capacity snapshot of the partition scratch — the single source of
/// truth for the growth regression hook (a buffer missing here would
/// silently escape `Engine::step_alloc_growth`). `&Vec` on purpose:
/// slices have no `capacity()`.
#[allow(clippy::ptr_arg)]
fn partition_caps(
    online_decodes: &Vec<RequestId>,
    online_prefills: &Vec<RequestId>,
    offline_decodes: &Vec<RequestId>,
    offline_prefills: &Vec<RequestId>,
    online_prefill_chunks: &Vec<(RequestId, usize)>,
) -> [usize; 5] {
    [
        online_decodes.capacity(),
        online_prefills.capacity(),
        offline_decodes.capacity(),
        offline_prefills.capacity(),
        online_prefill_chunks.capacity(),
    ]
}

/// Minimum useful SLO slack; below this the budget is treated as violated
/// anyway and offline admission stops.
pub(crate) const MIN_BUDGET: f64 = 1e-4;
/// Score epsilon: protects Eq. 4's division when a mutation adds ~no time.
pub(crate) const EPS_TIME: f64 = 1e-6;

impl Scheduler {
    pub fn new(
        cfg: SchedulerConfig,
        slo: Slo,
        time_model: TimeModel,
        block_size: usize,
    ) -> Self {
        Scheduler {
            cfg,
            slo,
            time_model,
            block_size,
            running_offline: Vec::new(),
            running: Vec::new(),
            offline_cap: usize::MAX,
            offline_admit_paused: false,
            scratch: SchedScratch::default(),
        }
    }

    /// Set the offline tokens-per-batch cap (SLO-guard actuator).
    /// `usize::MAX` disarms it.
    pub fn set_offline_cap(&mut self, cap: usize) {
        self.offline_cap = cap;
    }

    pub fn offline_cap(&self) -> usize {
        self.offline_cap
    }

    /// Pause/resume new offline admissions (SLO-guard drain actuator).
    pub fn set_offline_admit_paused(&mut self, paused: bool) {
        self.offline_admit_paused = paused;
    }

    pub fn offline_admit_paused(&self) -> bool {
        self.offline_admit_paused
    }

    /// Times the partition scratch had to grow a buffer (regression hook,
    /// like `Request::key_compute_count`): constant across steady-state
    /// iterations once the batch shape has peaked.
    pub fn scratch_grows(&self) -> u64 {
        self.scratch.grows
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Track `id` in the sorted running set (idempotent).
    fn note_running(&mut self, id: RequestId) {
        let pos = self.running.partition_point(|&r| r < id);
        if self.running.get(pos) != Some(&id) {
            self.running.insert(pos, id);
        }
    }

    /// Untrack `id` from the sorted running set.
    fn drop_running(&mut self, id: RequestId) {
        if let Ok(pos) = self.running.binary_search(&id) {
            self.running.remove(pos);
        }
    }

    /// Register a request that was marked `Running` outside the scheduler
    /// (test fixtures / benches that seed the store directly). Normal
    /// admissions are tracked automatically.
    pub fn adopt_running(&mut self, id: RequestId) {
        self.note_running(id);
    }

    /// Forget a request that finished (engine calls this on completion).
    pub fn on_finished(&mut self, id: RequestId) {
        self.running_offline.retain(|&r| r != id);
        self.drop_running(id);
    }

    /// Number of offline requests currently admitted.
    pub fn running_offline_count(&self) -> usize {
        self.running_offline.len()
    }

    /// Preempt the most recently admitted offline request (recompute mode):
    /// release KV, reset progress, push back into the pool. The interned
    /// key path makes the re-pooling free of prompt re-hashing.
    fn preempt_one_offline(
        &mut self,
        store: &mut RequestStore,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
        out: &mut Outcome,
    ) -> bool {
        let Some(victim) = self.running_offline.pop() else {
            return false;
        };
        let req = store.get_mut(victim);
        req.preempt();
        kv.release(victim, false);
        let keys = req.content_key_path(self.block_size).to_vec();
        pool.add(victim, req.prompt.total_len, keys);
        self.drop_running(victim);
        out.preempted.push(victim);
        true
    }

    /// SLO budget for the iteration: tightest slack among online requests
    /// that make progress in this batch (paper §5.1).
    fn slo_budget(
        &self,
        now: f64,
        store: &RequestStore,
        online_decodes: &[RequestId],
        online_prefills: &[(RequestId, usize)],
    ) -> f64 {
        let mut budget = f64::INFINITY;
        for &r in online_decodes {
            budget = budget.min(store.get(r).next_token_deadline(&self.slo) - now);
        }
        for &(r, chunk) in online_prefills {
            let req = store.get(r);
            // If this chunk completes the prefill, the first token lands at
            // the end of this iteration: it must beat the TTFT deadline.
            if req.remaining_prefill() <= chunk {
                budget = budget.min(req.arrival + self.slo.ttft - now);
            }
        }
        budget
    }

    /// Build this iteration's plan. Mutates request states, the pool, and
    /// the KV manager (admissions allocate, preemptions release).
    /// Convenience wrapper over [`Scheduler::schedule_into`] for callers
    /// that do not recycle an [`Outcome`] (tests, benches, fixtures).
    pub fn schedule(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        online_queue: &mut VecDeque<RequestId>,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
    ) -> Outcome {
        let mut out = Outcome::default();
        self.schedule_into(now, store, online_queue, pool, kv, &mut out);
        out
    }

    /// [`Scheduler::schedule`] into a caller-owned [`Outcome`]: every
    /// vector in `out` (plan items, batch shape, admission/preemption
    /// lists) is cleared and refilled in place, and the partition lists
    /// come from the scheduler's own scratch — an engine that passes the
    /// same `Outcome` every iteration allocates nothing in steady state.
    // lint: hot-path
    pub fn schedule_into(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        online_queue: &mut VecDeque<RequestId>,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
        out: &mut Outcome,
    ) {
        out.admitted_online.clear();
        out.admitted_offline.clear();
        out.preempted.clear();
        out.skipped_offline = 0;
        out.trials = 0;
        out.plan.est_time = 0.0;
        let mut items = std::mem::take(&mut out.plan.items);
        items.clear();
        let mut shape = TrialShape::recycled(std::mem::take(&mut out.plan.shape));
        let mut online_decodes = std::mem::take(&mut self.scratch.online_decodes);
        let mut online_prefills = std::mem::take(&mut self.scratch.online_prefills);
        let mut offline_decodes = std::mem::take(&mut self.scratch.offline_decodes);
        let mut offline_prefills = std::mem::take(&mut self.scratch.offline_prefills);
        let mut online_prefill_chunks = std::mem::take(&mut self.scratch.online_prefill_chunks);
        online_decodes.clear();
        online_prefills.clear();
        offline_decodes.clear();
        offline_prefills.clear();
        online_prefill_chunks.clear();
        let caps = partition_caps(
            &online_decodes,
            &online_prefills,
            &offline_decodes,
            &offline_prefills,
            &online_prefill_chunks,
        );

        // ---- 1. partition the carried-over running set ------------------
        // `self.running` is maintained sorted across iterations (the "last
        // batch" observation): no store scan, no re-sort. Entries that left
        // the running state without notice (direct store mutation in tests)
        // are scrubbed lazily here.
        self.running
            .retain(|&id| store.try_get(id).map_or(false, |r| r.state == ReqState::Running));
        debug_assert_eq!(
            self.running,
            {
                let mut v = store.ids_in_state(ReqState::Running);
                v.sort_unstable();
                v
            },
            "scheduler running-set index diverged from the store \
             (use Scheduler::adopt_running after marking a request Running directly)"
        );
        for &id in &self.running {
            let r = store.get(id);
            match (r.class, r.in_prefill()) {
                (TaskClass::Online, false) => online_decodes.push(id),
                (TaskClass::Online, true) => online_prefills.push(id),
                (TaskClass::Offline, false) => offline_decodes.push(id),
                (TaskClass::Offline, true) => offline_prefills.push(id),
            }
        }

        // ---- 2. decode block growth (next token's KV slot) --------------
        // Idempotent: grow only when held blocks cannot hold seq_len + 1.
        // Online decode growth may preempt offline requests; offline decode
        // growth failure preempts the request itself.
        for &id in &online_decodes {
            let needed = self.blocks_for(store.get(id).seq_len() + 1);
            while kv.held_blocks(id) < needed {
                let missing = needed - kv.held_blocks(id);
                if kv.grow(id, TaskClass::Online, missing, now) {
                    break;
                }
                if !self.preempt_one_offline(store, pool, kv, out) {
                    break; // genuinely out of memory: decode stalls
                }
            }
        }
        offline_decodes.retain(|&id| {
            // The online growth loop above may have preempted this request
            // already; drop it from the batch without double-preempting.
            if store.get(id).state != ReqState::Running {
                return false;
            }
            let needed = self.blocks_for(store.get(id).seq_len() + 1);
            let held = kv.held_blocks(id);
            if held >= needed {
                return true;
            }
            if kv.grow(id, TaskClass::Offline, needed - held, now) {
                true
            } else {
                // Self-preempt: cheapest victim is the request that cannot
                // even hold its next token.
                let req = store.get_mut(id);
                req.preempt();
                kv.release(id, false);
                // lint: allow-alloc(preemption path, not steady state; pool takes ownership)
                let keys = req.content_key_path(self.block_size).to_vec();
                pool.add(id, req.prompt.total_len, keys);
                self.running_offline.retain(|&r| r != id);
                self.drop_running(id);
                out.preempted.push(id);
                false
            }
        });

        // ---- 3. online admission (FCFS), preempting offline on OOM ------
        while let Some(&head) = online_queue.front() {
            if online_decodes.len() + online_prefills.len() + 1 > self.cfg.max_batch {
                break;
            }
            let total_blocks = self.blocks_for(store.get(head).seq_len() + 1);
            let mut admitted = false;
            loop {
                // Interned path: the borrow is scoped to the allocate call
                // so preemption (which mutates the store) stays legal.
                let alloc = {
                    let keys = store.get(head).content_key_path(self.block_size);
                    kv.allocate(head, TaskClass::Online, keys, total_blocks, now)
                };
                match alloc {
                    Some(ff) => {
                        let r = store.get_mut(head);
                        r.state = ReqState::Running;
                        // Cap: even a full prefix hit recomputes >= 1 token
                        // (the logits source for the next token).
                        r.computed = if self.cfg.fast_forward {
                            ff.min(r.seq_len().saturating_sub(1))
                        } else {
                            0
                        };
                        r.reserve_output();
                        self.note_running(head);
                        admitted = true;
                        break;
                    }
                    None => {
                        if !self.preempt_one_offline(store, pool, kv, out) {
                            break;
                        }
                    }
                }
            }
            if !admitted {
                break; // memory full of online work; queue waits
            }
            online_queue.pop_front();
            out.admitted_online.push(head);
            if store.get(head).in_prefill() {
                online_prefills.push(head);
            } else {
                online_decodes.push(head); // fully cache-hit prompt
            }
        }

        // Online admission may have preempted carried-over offline work;
        // scrub anything no longer running from the batch lists.
        offline_decodes.retain(|&id| store.get(id).state == ReqState::Running);
        offline_prefills.retain(|&id| store.get(id).state == ReqState::Running);

        // ---- 4. mandatory online items ----------------------------------
        // One TrialShape is threaded through the whole search: candidate
        // mutations are applied in place and undone on rejection (O(1) via
        // the incremental Eq. 6-8 aggregates) instead of cloning the shape
        // per trial. Plans come out bit-identical to the clone-trial oracle
        // (`oracle::OracleScheduler`); the equivalence tests pin this down.
        let mut token_budget = self.cfg.max_batched_tokens;
        // Offline tokens-per-batch cap (SLO-guard actuator). Unguarded the
        // sentinel never binds: the `min`/`== 0` checks below are the whole
        // cost of the disarmed path.
        let mut offline_budget = self.offline_cap;

        for &id in &online_decodes {
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Decode,
            });
            let _ = shape.push_decode(store.get(id).seq_len());
            token_budget = token_budget.saturating_sub(1);
        }
        // FCFS order for online prefills (arrival order == id order here).
        online_prefills.sort_by_key(|&id| {
            let r = store.get(id);
            (r.arrival as u64, id)
        });
        for &id in &online_prefills {
            if token_budget == 0 {
                break;
            }
            let r = store.get(id);
            let chunk = r.remaining_prefill().min(self.cfg.chunk).min(token_budget);
            if chunk == 0 {
                continue;
            }
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Prefill { chunk },
            });
            let _ = shape.push_prefill(
                &self.time_model,
                PrefillItem {
                    chunk,
                    context: r.computed,
                },
            );
            token_budget -= chunk;
            online_prefill_chunks.push((id, chunk));
        }

        let budget = if self.cfg.kind.uses_estimator() {
            self.slo_budget(now, store, &online_decodes, &online_prefill_chunks)
        } else {
            f64::INFINITY
        };

        // ---- 5. offline work, cheapest first: resident decodes ----------
        let mut slots_left = self.cfg.max_batch.saturating_sub(items.len());
        for &id in &offline_decodes {
            if slots_left == 0 || token_budget == 0 || offline_budget == 0 {
                break;
            }
            let len = store.get(id).seq_len();
            let undo = shape.push_decode(len);
            if self.cfg.kind.uses_estimator() {
                out.trials += 1;
                if self.time_model.batch_time_inc(&shape) > budget {
                    shape.undo(undo);
                    out.skipped_offline += 1;
                    continue; // stays running & resident, idles this iteration
                }
            }
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Decode,
            });
            token_budget -= 1;
            offline_budget = offline_budget.saturating_sub(1);
            slots_left -= 1;
        }

        // ---- 6. continue running offline prefills ------------------------
        for &id in &offline_prefills {
            if slots_left == 0 || token_budget == 0 || offline_budget == 0 {
                break;
            }
            let r = store.get(id);
            let chunk = r
                .remaining_prefill()
                .min(self.cfg.chunk)
                .min(token_budget)
                .min(offline_budget);
            if chunk == 0 {
                continue;
            }
            let undo = shape.push_prefill(
                &self.time_model,
                PrefillItem {
                    chunk,
                    context: r.computed,
                },
            );
            if self.cfg.kind.uses_estimator() {
                out.trials += 1;
                if self.time_model.batch_time_inc(&shape) > budget {
                    shape.undo(undo);
                    out.skipped_offline += 1;
                    continue;
                }
            }
            items.push(PlanItem {
                req: id,
                kind: WorkKind::Prefill { chunk },
            });
            token_budget -= chunk;
            offline_budget -= chunk;
            slots_left -= 1;
        }

        // ---- 7. new offline admissions -----------------------------------
        if budget > MIN_BUDGET && !self.offline_admit_paused {
            match self.cfg.kind {
                SchedulerKind::Bs | SchedulerKind::BsE => self.admit_fcfs(
                    now,
                    store,
                    pool,
                    kv,
                    &mut items,
                    &mut shape,
                    &mut token_budget,
                    &mut offline_budget,
                    &mut slots_left,
                    budget,
                    out,
                ),
                SchedulerKind::BsES | SchedulerKind::Echo => self.admit_kv_aware(
                    now,
                    store,
                    pool,
                    kv,
                    &mut items,
                    &mut shape,
                    &mut token_budget,
                    &mut offline_budget,
                    &mut slots_left,
                    budget,
                    out,
                ),
            }
        }

        out.plan.est_time = if self.cfg.kind.uses_estimator() {
            self.time_model.batch_time_inc(&shape)
        } else {
            0.0
        };
        out.plan.items = items;
        out.plan.shape = shape.into_shape();
        // Capacities never shrink, so any change means a buffer grew.
        let after = partition_caps(
            &online_decodes,
            &online_prefills,
            &offline_decodes,
            &offline_prefills,
            &online_prefill_chunks,
        );
        if after != caps {
            self.scratch.grows += 1;
        }
        self.scratch.online_decodes = online_decodes;
        self.scratch.online_prefills = online_prefills;
        self.scratch.offline_decodes = offline_decodes;
        self.scratch.offline_prefills = offline_prefills;
        self.scratch.online_prefill_chunks = online_prefill_chunks;
    }

    /// BS / BS+E: admit pool head FCFS while memory (and, for BS+E, the
    /// SLO estimate) allows.
    #[allow(clippy::too_many_arguments)]
    fn admit_fcfs(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
        items: &mut Vec<PlanItem>,
        shape: &mut TrialShape,
        token_budget: &mut usize,
        offline_budget: &mut usize,
        slots_left: &mut usize,
        budget: f64,
        out: &mut Outcome,
    ) {
        while *slots_left > 0 && *token_budget > 0 && *offline_budget > 0 {
            let Some(head) = pool.fcfs_head() else { break };
            let (prompt_len, seq_len) = {
                let r = store.get(head);
                (r.prompt.total_len, r.seq_len())
            };
            let total_blocks = self.blocks_for(seq_len + 1);
            let hit_blocks = {
                let keys = store.get(head).content_key_path(self.block_size);
                kv.peek_prefix(&keys[..keys.len().min(total_blocks)])
            };
            let ff = if self.cfg.fast_forward {
                (hit_blocks * self.block_size).min(seq_len - 1)
            } else {
                0
            };
            let chunk = (seq_len - ff)
                .min(self.cfg.chunk)
                .min(*token_budget)
                .min(*offline_budget);
            // estimator check (BS skips: budget = inf)
            let undo = if chunk > 0 {
                shape.push_prefill(
                    &self.time_model,
                    PrefillItem {
                        chunk,
                        context: ff,
                    },
                )
            } else {
                shape.push_decode(seq_len)
            };
            if self.cfg.kind.uses_estimator() {
                out.trials += 1;
                if self.time_model.batch_time_inc(shape) > budget {
                    shape.undo(undo);
                    break; // FCFS: if the head does not fit, stop
                }
            }
            let allocated = {
                let keys = store.get(head).content_key_path(self.block_size);
                kv.allocate(head, TaskClass::Offline, keys, total_blocks, now)
            };
            if allocated.is_none() {
                shape.undo(undo);
                break; // memory: offline never preempts anything
            }
            pool.remove(head, prompt_len);
            let r = store.get_mut(head);
            r.state = ReqState::Running;
            r.computed = ff;
            r.reserve_output();
            self.running_offline.push(head);
            self.note_running(head);
            out.admitted_offline.push(head);
            if chunk > 0 {
                items.push(PlanItem {
                    req: head,
                    kind: WorkKind::Prefill { chunk },
                });
                *token_budget -= chunk;
                *offline_budget -= chunk;
            } else {
                items.push(PlanItem {
                    req: head,
                    kind: WorkKind::Decode,
                });
                *token_budget -= 1;
                *offline_budget = offline_budget.saturating_sub(1);
            }
            *slots_left -= 1;
        }
    }

    /// BS+E+S / Echo: evaluate pool candidates (prefix-cached heads + FCFS
    /// heads per bucket) and admit by Eq. 4 score while feasible. Each
    /// candidate is scored by an apply/undo delta on the shared
    /// [`TrialShape`]; the winner's mutation is re-applied at commit (the
    /// base shape is unchanged between evaluation and commit, so the
    /// re-push reproduces the winning trial exactly).
    #[allow(clippy::too_many_arguments)]
    fn admit_kv_aware(
        &mut self,
        now: f64,
        store: &mut RequestStore,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
        items: &mut Vec<PlanItem>,
        shape: &mut TrialShape,
        token_budget: &mut usize,
        offline_budget: &mut usize,
        slots_left: &mut usize,
        budget: f64,
        out: &mut Outcome,
    ) {
        while *slots_left > 0 && *token_budget > 0 && *offline_budget > 0 {
            let candidates = pool.candidates(kv, self.cfg.mutation_budget);
            if candidates.is_empty() {
                break;
            }
            let base_time = self.time_model.batch_time_inc(shape);
            // One availability snapshot per admission round, shared by
            // every candidate trial below (the candidate loop is read-only
            // w.r.t. the KV manager, so the snapshot stays valid through
            // the winning `allocate`). `KvManager::availability_calls`
            // pins this: the count must not scale with candidate count.
            let avail = kv.availability();
            // (score, id, ff, chunk, seq_len)
            let mut best: Option<(f64, RequestId, usize, usize, usize)> = None;
            for id in candidates {
                let (seq_len, total_blocks, hit_blocks) = {
                    let r = store.get(id);
                    let seq_len = r.seq_len();
                    let total_blocks = self.blocks_for(seq_len + 1);
                    let keys = r.content_key_path(self.block_size);
                    (
                        seq_len,
                        total_blocks,
                        kv.peek_prefix(&keys[..keys.len().min(total_blocks)]),
                    )
                };
                let ff = if self.cfg.fast_forward {
                    (hit_blocks * self.block_size).min(seq_len - 1)
                } else {
                    0
                };
                let fresh = total_blocks - hit_blocks;
                if fresh > avail.for_offline() {
                    continue;
                }
                let chunk = (seq_len - ff)
                    .min(self.cfg.chunk)
                    .min(*token_budget)
                    .min(*offline_budget);
                let undo = if chunk > 0 {
                    shape.push_prefill(
                        &self.time_model,
                        PrefillItem {
                            chunk,
                            context: ff,
                        },
                    )
                } else {
                    shape.push_decode(seq_len)
                };
                out.trials += 1;
                let t = self.time_model.batch_time_inc(shape);
                shape.undo(undo);
                if t > budget {
                    continue;
                }
                // Eq. 4: benefit = tokens made progress (cache fast-forward
                // is free benefit); punishment = tokens future requests
                // will have to re-prefill because of our evictions.
                let need_evict = fresh.saturating_sub(avail.free);
                let punish = kv.eviction_preview(need_evict) as f64;
                let benefit = (ff + chunk.max(1)) as f64;
                let dt = (t - base_time).max(EPS_TIME);
                let score = (benefit - punish) / dt;
                if score <= 0.0 {
                    continue;
                }
                if best.as_ref().map_or(true, |b| score > b.0) {
                    best = Some((score, id, ff, chunk, seq_len));
                }
            }
            let Some((_, id, ff, chunk, seq_len)) = best else { break };
            let (prompt_len, total_blocks) = {
                let r = store.get(id);
                (r.prompt.total_len, self.blocks_for(r.seq_len() + 1))
            };
            let allocated = {
                let keys = store.get(id).content_key_path(self.block_size);
                kv.allocate(id, TaskClass::Offline, keys, total_blocks, now)
            };
            if allocated.is_none() {
                break;
            }
            pool.remove(id, prompt_len);
            let r = store.get_mut(id);
            r.state = ReqState::Running;
            r.computed = ff;
            r.reserve_output();
            self.running_offline.push(id);
            self.note_running(id);
            out.admitted_offline.push(id);
            // Commit the winning mutation (base unchanged since scoring).
            if chunk > 0 {
                let _ = shape.push_prefill(
                    &self.time_model,
                    PrefillItem {
                        chunk,
                        context: ff,
                    },
                );
                items.push(PlanItem {
                    req: id,
                    kind: WorkKind::Prefill { chunk },
                });
                *token_budget -= chunk;
                *offline_budget -= chunk;
            } else {
                let _ = shape.push_decode(seq_len);
                items.push(PlanItem {
                    req: id,
                    kind: WorkKind::Decode,
                });
                *token_budget -= 1;
                *offline_budget = offline_budget.saturating_sub(1);
            }
            *slots_left -= 1;
        }
    }

    /// Emergency brownout actuator: preempt *every* running offline
    /// request (recompute mode), returning the victims newest-admitted
    /// first. Coordinator-phase only — not part of the per-iteration hot
    /// path, so the returned `Vec` is fine.
    pub fn preempt_all_offline(
        &mut self,
        store: &mut RequestStore,
        pool: &mut OfflinePool,
        kv: &mut KvManager,
    ) -> Vec<RequestId> {
        let mut victims = Vec::with_capacity(self.running_offline.len());
        while let Some(victim) = self.running_offline.pop() {
            let req = store.get_mut(victim);
            req.preempt();
            kv.release(victim, false);
            let keys = req.content_key_path(self.block_size).to_vec();
            pool.add(victim, req.prompt.total_len, keys);
            self.drop_running(victim);
            victims.push(victim);
        }
        victims
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::core::{PromptSpec, Request};
    use crate::estimator::TimeModel;
    use crate::kvcache::EvictionPolicy;

    struct Fixture {
        sched: Scheduler,
        store: RequestStore,
        queue: VecDeque<RequestId>,
        pool: OfflinePool,
        kv: KvManager,
    }

    fn fixture(kind: SchedulerKind, capacity_blocks: usize) -> Fixture {
        let mut cfg = SystemConfig::a100_llama8b();
        cfg.scheduler.kind = kind;
        cfg.scheduler.max_batch = 8;
        cfg.scheduler.max_batched_tokens = 512;
        cfg.scheduler.chunk = 128;
        let policy = if kind.uses_task_aware_cache() {
            EvictionPolicy::TaskAware
        } else {
            EvictionPolicy::Lru
        };
        Fixture {
            sched: Scheduler::new(
                cfg.scheduler.clone(),
                cfg.slo,
                TimeModel::new(cfg.time_model),
                cfg.cache.block_size,
            ),
            store: RequestStore::new(),
            queue: VecDeque::new(),
            pool: OfflinePool::default_buckets(),
            kv: KvManager::new(capacity_blocks, cfg.cache.block_size, policy),
        }
    }

    fn add_online(f: &mut Fixture, arrival: f64, prompt: usize, out: usize) -> RequestId {
        let id = f.store.fresh_id();
        f.store.insert(Request::new(
            id,
            TaskClass::Online,
            arrival,
            PromptSpec::sim(prompt, None),
            out,
        ));
        f.queue.push_back(id);
        id
    }

    fn add_offline(f: &mut Fixture, prompt: usize, out: usize) -> RequestId {
        let id = f.store.fresh_id();
        let spec = PromptSpec::sim(prompt, None);
        let keys = spec.content_keys(id, prompt, 16);
        f.kv.register_future(&keys);
        f.store
            .insert(Request::new(id, TaskClass::Offline, 0.0, spec, out));
        f.pool.add(id, prompt, keys);
        id
    }

    #[test]
    fn admits_online_fcfs_and_prefills() {
        let mut f = fixture(SchedulerKind::Echo, 1000);
        let a = add_online(&mut f, 0.0, 300, 10);
        let b = add_online(&mut f, 0.1, 300, 10);
        let out = f
            .sched
            .schedule(0.2, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(out.admitted_online, vec![a, b]);
        assert_eq!(out.plan.n_prefills(), 2);
        // chunked: 128-token chunks
        assert_eq!(out.plan.total_tokens(), 256);
        assert_eq!(f.store.get(a).state, ReqState::Running);
    }

    #[test]
    fn offline_admitted_when_idle() {
        let mut f = fixture(SchedulerKind::Echo, 1000);
        let o = add_offline(&mut f, 200, 20);
        let out = f
            .sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(out.admitted_offline, vec![o]);
        assert!(f.pool.is_empty());
        assert_eq!(out.plan.n_prefills(), 1);
    }

    #[test]
    fn online_preempts_offline_on_oom() {
        // capacity: 40 blocks = 640 tokens
        let mut f = fixture(SchedulerKind::Echo, 40);
        let o = add_offline(&mut f, 500, 20);
        let out = f
            .sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(out.admitted_offline, vec![o]);
        // Online arrives needing 400 tokens: must preempt the offline req.
        let a = add_online(&mut f, 1.0, 400, 10);
        let out = f
            .sched
            .schedule(1.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(out.admitted_online, vec![a]);
        assert_eq!(out.preempted, vec![o]);
        assert_eq!(f.store.get(o).state, ReqState::Preempted);
        assert_eq!(f.store.get(o).computed, 0);
        assert_eq!(f.pool.len(), 1, "victim returns to the pool");
        f.kv.check_invariants().unwrap();
    }

    #[test]
    fn slo_blocks_offline_admission_bse() {
        let mut f = fixture(SchedulerKind::BsE, 10_000);
        // Online decode with a nearly-due deadline.
        let a = add_online(&mut f, 0.0, 100, 50);
        f.sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        let r = f.store.get_mut(a);
        r.computed = 100; // prefill done
        r.record_token(0.9, None);
        // A huge offline prefill would blow the TPOT deadline.
        add_offline(&mut f, 8000, 100);
        let now = 0.94; // deadline = arrival + 1.0 + 1*0.05 = 1.05 → slack 0.11s
        let out = f
            .sched
            .schedule(now, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        // prefill chunk of 128 over 8000-context ≈ fine, but the admission
        // estimate uses the whole batch; with slack 0.11 s the chunk fits —
        // tighten: move to 1.049 (slack 1 ms < c=6 ms floor).
        let _ = out;
        let out2 = f
            .sched
            .schedule(1.049, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert!(out2.admitted_offline.is_empty(), "no offline under 1ms slack");
        assert!(out2.plan.n_decodes() >= 1, "online decode still runs");
    }

    #[test]
    fn bs_ignores_slo() {
        let mut f = fixture(SchedulerKind::Bs, 10_000);
        let a = add_online(&mut f, 0.0, 100, 50);
        f.sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        let r = f.store.get_mut(a);
        r.computed = 100;
        r.record_token(0.9, None);
        add_offline(&mut f, 8000, 100);
        let out = f
            .sched
            .schedule(1.049, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(out.admitted_offline.len(), 1, "BS admits regardless of SLO");
    }

    #[test]
    fn kv_aware_prefers_cached_candidate() {
        let mut f = fixture(SchedulerKind::Echo, 10_000);
        // Two offline groups; warm the cache with group g's prefix.
        let g: u64 = 99;
        let id1 = f.store.fresh_id();
        let spec1 = PromptSpec::sim(320, Some((g, 320)));
        let keys1 = spec1.content_keys(id1, 320, 16);
        f.kv.register_future(&keys1);
        f.store
            .insert(Request::new(id1, TaskClass::Offline, 0.0, spec1, 10));
        f.pool.add(id1, 320, keys1.clone());
        // Unrelated offline request, same size.
        let id2 = add_offline(&mut f, 320, 10);
        // Warm cache: simulate sibling of group g having run.
        let warm = f.store.fresh_id();
        f.kv.allocate(warm, TaskClass::Offline, &keys1[..10], 10, 0.0)
            .unwrap();
        f.kv.release(warm, true);
        let out = f
            .sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert!(!out.admitted_offline.is_empty());
        assert_eq!(
            out.admitted_offline[0], id1,
            "cached-prefix candidate must win (id2={id2})"
        );
        // Fast-forward applied:
        assert_eq!(f.store.get(id1).computed, 160);
    }

    #[test]
    fn decode_growth_preempts_offline_for_online() {
        let mut f = fixture(SchedulerKind::Echo, 11);
        // Online request: 159 prompt + 1 = 10 blocks at admission.
        let a = add_online(&mut f, 0.0, 159, 50);
        f.sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        f.store.get_mut(a).computed = 159; // prefill complete -> decode-ready
        // Offline fills the last free block.
        let o = add_offline(&mut f, 10, 5);
        f.sched
            .schedule(0.6, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(f.store.get(o).state, ReqState::Running);
        // A token lands: seq_len 160 fills the 10 blocks; the next decode
        // needs an 11th block -> offline must be preempted.
        f.store.get_mut(a).record_token(0.65, None);
        let out = f
            .sched
            .schedule(0.7, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert!(out.preempted.contains(&o), "preempted={:?}", out.preempted);
        f.kv.check_invariants().unwrap();
    }

    #[test]
    fn growth_is_idempotent_when_decode_skipped() {
        let mut f = fixture(SchedulerKind::Echo, 100);
        let a = add_online(&mut f, 0.0, 31, 50);
        f.sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        f.store.get_mut(a).computed = 31;
        f.store.get_mut(a).record_token(0.1, None); // seq 32 = 2 blocks full
        // Two schedules without token progress must not leak blocks.
        f.sched
            .schedule(0.2, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        let held_once = f.kv.held_blocks(a);
        f.sched
            .schedule(0.3, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(f.kv.held_blocks(a), held_once);
        assert_eq!(held_once, 3); // blocks_for(33)
        f.kv.check_invariants().unwrap();
    }

    #[test]
    fn availability_snapshot_per_round_not_per_candidate() {
        // Same capacity, same admission budget: pools of very different
        // sizes must cost the same number of availability() snapshots —
        // the KV-aware trial path takes one per admission round and reuses
        // it across every candidate, never one per candidate.
        let count_for = |pool_size: usize| {
            let mut f = fixture(SchedulerKind::Echo, 10_000);
            f.sched.cfg.max_batch = 2; // two admissions, then slots run out
            for _ in 0..pool_size {
                add_offline(&mut f, 100, 4);
            }
            let before = f.kv.availability_calls();
            let out = f
                .sched
                .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
            assert_eq!(out.admitted_offline.len(), 2);
            f.kv.availability_calls() - before
        };
        let small = count_for(4);
        assert_eq!(
            small,
            count_for(40),
            "availability call count must not scale with the candidate pool"
        );
        // One snapshot per round + one inside each successful allocate.
        assert_eq!(small, 4);
    }

    #[test]
    fn offline_cap_and_pause_gate_offline_work() {
        let mut f = fixture(SchedulerKind::Echo, 1000);
        add_offline(&mut f, 200, 20);
        // Paused admission: the pool head stays put.
        f.sched.set_offline_admit_paused(true);
        let out = f
            .sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert!(out.admitted_offline.is_empty());
        assert_eq!(f.pool.len(), 1);
        // Unpaused but capped: the admitted prefill chunk honors the cap
        // (cfg.chunk is 128, cap is 64).
        f.sched.set_offline_admit_paused(false);
        f.sched.set_offline_cap(64);
        let out = f
            .sched
            .schedule(0.1, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(out.admitted_offline.len(), 1);
        assert_eq!(out.plan.total_tokens(), 64);
        // Cap 0: resident offline work idles entirely.
        f.sched.set_offline_cap(0);
        let out = f
            .sched
            .schedule(0.2, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert!(out.plan.items.is_empty());
    }

    #[test]
    fn preempt_all_offline_returns_everything_to_the_pool() {
        let mut f = fixture(SchedulerKind::Echo, 1000);
        for _ in 0..3 {
            add_offline(&mut f, 100, 10);
        }
        let out = f
            .sched
            .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert_eq!(out.admitted_offline.len(), 3);
        let victims = f
            .sched
            .preempt_all_offline(&mut f.store, &mut f.pool, &mut f.kv);
        assert_eq!(victims.len(), 3);
        assert_eq!(f.pool.len(), 3);
        assert_eq!(f.sched.running_offline_count(), 0);
        for &v in &victims {
            assert_eq!(f.store.get(v).state, ReqState::Preempted);
        }
        f.kv.check_invariants().unwrap();
        // Next schedule re-admits from the pool as usual.
        let out = f
            .sched
            .schedule(0.5, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
        assert!(!out.admitted_offline.is_empty());
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let run = || {
            let mut f = fixture(SchedulerKind::Echo, 500);
            add_online(&mut f, 0.0, 300, 10);
            for _ in 0..5 {
                add_offline(&mut f, 200, 10);
            }
            let out = f
                .sched
                .schedule(0.0, &mut f.store, &mut f.queue, &mut f.pool, &mut f.kv);
            (
                out.plan.items.iter().map(|i| i.req).collect::<Vec<_>>(),
                out.admitted_offline,
            )
        };
        assert_eq!(run(), run());
    }
}

//! Threaded serving front-end (tokio is not reachable offline; the
//! coordinator is a std::thread event loop with mpsc channels, which is all
//! a single-instance serving leader needs).
//!
//! Architecture:
//!   * client threads submit [`ServerRequest`]s through a channel (online
//!     requests carry a completion channel for the response);
//!   * the coordinator thread owns the [`Engine`] and alternates between
//!     draining the submission channel and running engine steps;
//!   * `shutdown()` drains remaining work, then joins and returns the
//!     engine (metrics intact).

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use crate::core::{PromptSpec, Request, RequestId, TaskClass, Token};
use crate::engine::{Engine, ExecutionBackend};

/// A completed request's client-visible result.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub tokens: Vec<Token>,
    pub ttft: Option<f64>,
    pub mean_tpot: Option<f64>,
}

pub enum ServerRequest {
    Online {
        prompt: PromptSpec,
        max_new_tokens: usize,
        reply: Sender<Completion>,
    },
    Offline {
        prompt: PromptSpec,
        max_new_tokens: usize,
    },
    Shutdown,
}

pub struct ServerHandle<B: ExecutionBackend + Send + 'static> {
    pub tx: Sender<ServerRequest>,
    join: JoinHandle<Engine<B>>,
}

impl<B: ExecutionBackend + Send + 'static> ServerHandle<B> {
    /// Submit an online request; returns the channel the completion will
    /// arrive on.
    pub fn submit_online(
        &self,
        prompt: PromptSpec,
        max_new_tokens: usize,
    ) -> Receiver<Completion> {
        let (reply, rx) = channel();
        self.tx
            .send(ServerRequest::Online {
                prompt,
                max_new_tokens,
                reply,
            })
            .expect("server gone");
        rx
    }

    pub fn submit_offline(&self, prompt: PromptSpec, max_new_tokens: usize) {
        self.tx
            .send(ServerRequest::Offline {
                prompt,
                max_new_tokens,
            })
            .expect("server gone");
    }

    /// Drain outstanding work and return the engine.
    pub fn shutdown(self) -> Engine<B> {
        let _ = self.tx.send(ServerRequest::Shutdown);
        self.join.join().expect("coordinator panicked")
    }
}

/// Spawn the coordinator thread around an engine. The engine's virtual
/// clock is advanced by execution only; arrival timestamps use a wall
/// clock anchored at server start so TTFT measurements are real.
pub fn spawn<B: ExecutionBackend + Send + 'static>(mut engine: Engine<B>) -> ServerHandle<B> {
    let (tx, rx) = channel::<ServerRequest>();
    let join = std::thread::spawn(move || {
        let t0 = std::time::Instant::now();
        let mut replies: std::collections::HashMap<RequestId, Sender<Completion>> =
            Default::default();
        let mut shutting_down = false;
        loop {
            // 1. drain submissions
            loop {
                match rx.try_recv() {
                    Ok(ServerRequest::Online {
                        prompt,
                        max_new_tokens,
                        reply,
                    }) => {
                        let now = t0.elapsed().as_secs_f64();
                        // Engine clock lags wall clock when idle; anchor
                        // arrivals to whichever is ahead so deadlines are
                        // consistent.
                        let arrival = now.max(engine.clock);
                        let id = engine.store.fresh_id();
                        replies.insert(id, reply);
                        engine.submit_online(Request::new(
                            id,
                            TaskClass::Online,
                            arrival,
                            prompt,
                            max_new_tokens,
                        ));
                    }
                    Ok(ServerRequest::Offline {
                        prompt,
                        max_new_tokens,
                    }) => {
                        let id = engine.store.fresh_id();
                        let arrival = engine.clock;
                        engine.submit_offline(Request::new(
                            id,
                            TaskClass::Offline,
                            arrival,
                            prompt,
                            max_new_tokens,
                        ));
                    }
                    Ok(ServerRequest::Shutdown) => shutting_down = true,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutting_down = true;
                        break;
                    }
                }
            }

            // Keep the virtual clock moving with wall time while serving
            // live traffic (otherwise deadlines are meaningless).
            engine.clock = engine.clock.max(t0.elapsed().as_secs_f64());

            // 2. one engine step
            let progressed = engine.step().unwrap_or(false);

            // 3. deliver completions
            let done: Vec<RequestId> = replies
                .keys()
                .copied()
                .filter(|&id| engine.store.get(id).is_finished())
                .collect();
            for id in done {
                let r = engine.store.get(id);
                let completion = Completion {
                    id,
                    tokens: r.out_tokens.clone(),
                    ttft: r.ttft(),
                    mean_tpot: r.mean_tpot(),
                };
                if let Some(reply) = replies.remove(&id) {
                    let _ = reply.send(completion);
                }
            }

            if !progressed {
                if shutting_down {
                    break;
                }
                // Idle: block briefly for new work.
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        engine
    });
    ServerHandle { tx, join }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::engine::sim::SimBackend;
    use crate::estimator::TimeModel;

    #[test]
    fn serve_roundtrip_online_and_offline() {
        let cfg = SystemConfig::a100_llama8b();
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), 3, 0.0);
        let engine = Engine::new(cfg, backend);
        let h = spawn(engine);

        let rx1 = h.submit_online(PromptSpec::sim(200, None), 8);
        let rx2 = h.submit_online(PromptSpec::sim(400, None), 4);
        h.submit_offline(PromptSpec::sim(1000, None), 16);

        let c1 = rx1.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let c2 = rx2.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(c1.tokens.len(), 8);
        assert_eq!(c2.tokens.len(), 4);
        assert!(c1.ttft.is_some());

        let engine = h.shutdown();
        assert_eq!(engine.metrics.online_completed, 2);
        assert_eq!(engine.metrics.offline_completed, 1);
        engine.kv.check_invariants().unwrap();
    }
}

//! Threaded serving front-end (tokio is not reachable offline; the
//! coordinator is a std::thread event loop with mpsc channels, which is all
//! a single-instance serving leader needs).
//!
//! Architecture:
//!   * clients submit [`SubmitSpec`]s through a channel and hold
//!     [`Ticket`]s; per-token [`TokenEvent`]s stream back — both to the
//!     handle's shared event queue (the [`Serve::pump`] path) and, for
//!     subscribed tickets, to a per-ticket channel;
//!   * the coordinator thread owns the [`Engine`] and alternates between
//!     draining the submission channel and running engine steps;
//!   * a dropped per-ticket receiver is detected at the next event send and
//!     triggers `Engine::cancel`: the abandoned request's KV blocks, future
//!     interest, and pool/queue entries are released instead of burning
//!     decode slots to completion into a dead channel;
//!   * `shutdown()` drains remaining work, then joins and returns the
//!     engine (metrics intact).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::core::{ReqState, Request, RequestId, TaskClass};
use crate::engine::{Engine, ExecutionBackend};
use crate::faults::{CancelReason, ServeError};
use crate::serve::{
    collect_store_events, Cursor, EventSink, MetricsView, Serve, SubmitSpec, Ticket, TicketId,
    TokenEvent,
};
use crate::utils::hash::FxHashMap;

/// Bound on the shared (pump-consumed) event queue. Callers that only use
/// per-ticket streaming receivers never pump, so an unbounded queue would
/// grow with every token served; beyond this bound events are dropped from
/// the shared tee only (per-ticket subscribers and the outstanding-ticket
/// accounting are unaffected). An active pump consumer keeps the queue
/// near-empty.
const EVENT_QUEUE_BOUND: usize = 65_536;

/// Coordinator-side protocol. Construction stays inside this module: every
/// external caller goes through the [`Serve`] trait (or the streaming
/// helpers below), never through raw channel frames.
pub(crate) enum ServerRequest {
    Submit {
        id: RequestId,
        spec: SubmitSpec,
        stream: Option<Sender<TokenEvent>>,
    },
    Cancel(RequestId),
    Shutdown,
}

pub struct ServerHandle<B: ExecutionBackend + Send + 'static> {
    tx: Sender<ServerRequest>,
    events: Receiver<TokenEvent>,
    snap: Arc<Mutex<MetricsView>>,
    next_id: AtomicU64,
    /// Tickets submitted whose terminal event the coordinator has not yet
    /// published (incremented at submit, decremented by the coordinator —
    /// drives `drain` termination independently of who consumes events).
    outstanding: Arc<AtomicUsize>,
    t0: Instant,
    join: JoinHandle<Engine<B>>,
}

impl<B: ExecutionBackend + Send + 'static> ServerHandle<B> {
    /// Submit and stream: returns the ticket plus a dedicated per-ticket
    /// event channel. Dropping the receiver cancels the request (the
    /// coordinator notices at its next event for this ticket). Fails with
    /// [`ServeError::ServerGone`] once the coordinator has exited.
    pub fn submit_streaming(
        &self,
        spec: SubmitSpec,
    ) -> Result<(Ticket, Receiver<TokenEvent>), ServeError> {
        let (ev_tx, ev_rx) = channel();
        let ticket = self.submit_inner(spec, Some(ev_tx))?;
        Ok((ticket, ev_rx))
    }

    /// Submit without a dedicated stream; events still flow through
    /// [`Serve::pump`].
    pub fn submit_detached(&self, spec: SubmitSpec) -> Result<Ticket, ServeError> {
        self.submit_inner(spec, None)
    }

    fn submit_inner(
        &self,
        spec: SubmitSpec,
        stream: Option<Sender<TokenEvent>>,
    ) -> Result<Ticket, ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let class = spec.slo.task_class();
        let submitted_at = self.t0.elapsed().as_secs_f64();
        // Increment before the send: the coordinator may process (and even
        // complete) the submission before this function returns, and its
        // terminal-event decrement must never race ahead of the increment.
        self.outstanding.fetch_add(1, Ordering::Relaxed);
        if self
            .tx
            .send(ServerRequest::Submit { id, spec, stream })
            .is_err()
        {
            let _ = self.outstanding.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                Some(n.saturating_sub(1))
            });
            return Err(ServeError::ServerGone);
        }
        Ok(Ticket {
            id,
            class,
            submitted_at,
        })
    }

    /// Drain outstanding work and return the engine.
    pub fn shutdown(self) -> Engine<B> {
        let _ = self.tx.send(ServerRequest::Shutdown);
        // lint: allow-unwrap(join fails only if the coordinator panicked; propagate it)
        self.join.join().expect("coordinator panicked")
    }
}

impl<B: ExecutionBackend + Send + 'static> Serve for ServerHandle<B> {
    fn submit(&mut self, spec: SubmitSpec) -> anyhow::Result<Ticket> {
        Ok(self.submit_detached(spec)?)
    }

    /// Asynchronous: the request is withdrawn at the coordinator's next
    /// loop turn; the `Cancelled` event arrives through `pump`. Returns
    /// false only if the server is gone.
    fn cancel(&mut self, ticket: TicketId) -> bool {
        self.tx.send(ServerRequest::Cancel(ticket)).is_ok()
    }

    fn pump(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<bool> {
        let mut any = false;
        loop {
            match self.events.try_recv() {
                Ok(ev) => {
                    sink.on_event(&ev);
                    any = true;
                }
                Err(TryRecvError::Empty) => break,
                // Coordinator gone: no further events can ever arrive, so
                // never report busy (a drain would otherwise spin forever).
                Err(TryRecvError::Disconnected) => return Ok(any),
            }
        }
        Ok(any || self.outstanding.load(Ordering::Relaxed) > 0)
    }

    fn drain(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        loop {
            let busy = self.pump(sink)?;
            if self.outstanding.load(Ordering::Relaxed) == 0 {
                // Terminal events are published before the counter drops;
                // one more pump sweeps anything enqueued since.
                self.pump(sink)?;
                return Ok(());
            }
            if !busy {
                return Ok(());
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    /// Wall-clock deadline, measured in seconds since the server started.
    fn run_until(&mut self, deadline: f64, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        while self.t0.elapsed().as_secs_f64() < deadline {
            self.pump(sink)?;
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        self.pump(sink)?;
        Ok(())
    }

    fn snapshot(&self) -> MetricsView {
        // A poisoned lock means the coordinator panicked mid-update; the
        // last published view is still the best available answer.
        match self.snap.lock() {
            Ok(s) => s.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }
}

fn view_of<B: ExecutionBackend>(e: &Engine<B>) -> MetricsView {
    MetricsView::of_engine(e, "server")
}

/// Coordinator-side event delivery: tee to the ticket's subscriber
/// (reporting a dead client on non-terminal sends), publish into the
/// bounded shared queue, and settle the outstanding-ticket count on
/// terminal events (after the publish, so a drain that observes the count
/// at zero finds the event already enqueued). Returns the ticket id when
/// the subscriber turned out to be dead (abandoned request).
fn publish_event(
    ev: TokenEvent,
    streams: &mut FxHashMap<RequestId, Sender<TokenEvent>>,
    ev_tx: &SyncSender<TokenEvent>,
    outstanding: &AtomicUsize,
) -> Option<RequestId> {
    let id = ev.ticket();
    let mut abandoned = None;
    if let Some(s) = streams.get(&id) {
        if s.send(ev.clone()).is_err() && !ev.is_terminal() {
            abandoned = Some(id);
        }
    }
    let terminal = ev.is_terminal();
    let _ = ev_tx.try_send(ev); // full queue: shared tee drops, see bound doc
    if terminal {
        streams.remove(&id);
        // Saturating: defensive against double-terminal delivery.
        let _ = outstanding.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
            Some(n.saturating_sub(1))
        });
    }
    abandoned
}

/// Spawn the coordinator thread around an engine. The engine's virtual
/// clock is advanced by execution only; arrival timestamps use a wall
/// clock anchored at server start so TTFT measurements are real.
pub fn spawn<B: ExecutionBackend + Send + 'static>(mut engine: Engine<B>) -> ServerHandle<B> {
    let (tx, rx) = channel::<ServerRequest>();
    let (ev_tx, ev_rx) = sync_channel::<TokenEvent>(EVENT_QUEUE_BOUND);
    let snap = Arc::new(Mutex::new(MetricsView::default()));
    let snap_w = snap.clone();
    let outstanding = Arc::new(AtomicUsize::new(0));
    let outstanding_w = outstanding.clone();
    let join = std::thread::spawn(move || {
        let t0 = Instant::now();
        let mut streams: FxHashMap<RequestId, Sender<TokenEvent>> = FxHashMap::default();
        let mut cursors: BTreeMap<RequestId, Cursor> = BTreeMap::new();
        let mut shutting_down = false;
        loop {
            // 1. drain submissions / cancels
            loop {
                match rx.try_recv() {
                    Ok(ServerRequest::Submit { id, spec, stream }) => {
                        let class = spec.slo.task_class();
                        // Engine clock lags wall clock when idle; anchor
                        // arrivals to whichever is ahead so deadlines are
                        // consistent. Offline work is best-effort: its
                        // arrival is bookkeeping only.
                        let now = t0.elapsed().as_secs_f64();
                        let arrival = spec.arrival.unwrap_or(now).max(engine.clock);
                        let req =
                            Request::new(id, class, arrival, spec.prompt, spec.max_new_tokens);
                        match class {
                            TaskClass::Online => engine.submit_online(req),
                            TaskClass::Offline => engine.submit_offline(req),
                        }
                        if let Some(s) = stream {
                            streams.insert(id, s);
                        }
                        cursors.insert(id, Cursor::default());
                    }
                    Ok(ServerRequest::Cancel(id)) => {
                        if engine.cancel(id) {
                            cursors.remove(&id);
                            let _ = publish_event(
                                TokenEvent::Cancelled {
                                    ticket: id,
                                    at: engine.clock,
                                    reason: CancelReason::Client,
                                },
                                &mut streams,
                                &ev_tx,
                                &outstanding_w,
                            );
                        }
                    }
                    Ok(ServerRequest::Shutdown) => shutting_down = true,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutting_down = true;
                        break;
                    }
                }
            }

            // Keep the virtual clock moving with wall time while serving
            // live traffic (otherwise deadlines are meaningless).
            engine.clock = engine.clock.max(t0.elapsed().as_secs_f64());

            // 2. one engine step. An execution error is NOT "no work left":
            // rejecting queued requests on a transient backend hiccup would
            // destroy schedulable work, so errors skip step 4.
            let (progressed, step_err) = match engine.step() {
                Ok(p) => (p, false),
                Err(e) => {
                    log::error!("engine step failed: {e:#}");
                    (false, true)
                }
            };

            // 3. event delivery: bounded shared queue + per-ticket tees.
            // A dead subscriber means the client abandoned the request —
            // withdraw it.
            let mut evs: Vec<TokenEvent> = Vec::new();
            collect_store_events(&engine.store, &mut cursors, engine.clock, &mut evs);
            let mut abandoned: Vec<RequestId> = Vec::new();
            for ev in evs {
                if let Some(id) = publish_event(ev, &mut streams, &ev_tx, &outstanding_w) {
                    abandoned.push(id);
                }
            }
            for id in abandoned {
                streams.remove(&id);
                if engine.cancel(id) {
                    cursors.remove(&id);
                    let _ = publish_event(
                        TokenEvent::Cancelled {
                            ticket: id,
                            at: engine.clock,
                            reason: CancelReason::Client,
                        },
                        &mut streams,
                        &ev_tx,
                        &outstanding_w,
                    );
                }
            }

            // 4. reject unschedulable work. `step` returning Ok(false)
            // means no future arrivals and nothing runnable, so any
            // request still queued or pooled can NEVER be scheduled (e.g.
            // larger than KV memory) — withdraw it so its client sees a
            // terminal event instead of a stream that hangs forever.
            if !progressed && !step_err {
                let stuck: Vec<RequestId> = cursors
                    .keys()
                    .copied()
                    .filter(|&id| {
                        matches!(
                            engine.store.get(id).state,
                            ReqState::Queued | ReqState::Preempted
                        )
                    })
                    .collect();
                for id in stuck {
                    if engine.cancel(id) {
                        log::warn!("rejecting unschedulable request {id}");
                        cursors.remove(&id);
                        let _ = publish_event(
                            TokenEvent::Cancelled {
                                ticket: id,
                                at: engine.clock,
                                reason: CancelReason::Unschedulable,
                            },
                            &mut streams,
                            &ev_tx,
                            &outstanding_w,
                        );
                    }
                }
            }

            // 5. publish a load snapshot for Serve::snapshot
            if let Ok(mut s) = snap_w.lock() {
                *s = view_of(&engine);
            }

            if !progressed {
                if shutting_down {
                    break;
                }
                // Idle: block briefly for new work.
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        if let Ok(mut s) = snap_w.lock() {
            *s = view_of(&engine);
        }
        engine
    });
    ServerHandle {
        tx,
        events: ev_rx,
        snap,
        next_id: AtomicU64::new(0),
        outstanding,
        t0: Instant::now(),
        join,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::core::PromptSpec;
    use crate::engine::sim::SimBackend;
    use crate::estimator::TimeModel;
    use std::time::Duration;

    fn handle() -> ServerHandle<SimBackend> {
        let cfg = SystemConfig::a100_llama8b();
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), 3, 0.0);
        spawn(Engine::new(cfg, backend))
    }

    fn finish_of(rx: &Receiver<TokenEvent>) -> TokenEvent {
        loop {
            let ev = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            if ev.is_terminal() {
                return ev;
            }
        }
    }

    #[test]
    fn serve_roundtrip_online_and_offline() {
        let h = handle();
        let (t1, rx1) = h
            .submit_streaming(SubmitSpec::online(PromptSpec::sim(200, None), 8))
            .unwrap();
        let (t2, rx2) = h
            .submit_streaming(SubmitSpec::online(PromptSpec::sim(400, None), 4))
            .unwrap();
        h.submit_detached(SubmitSpec::offline(PromptSpec::sim(1000, None), 16))
            .unwrap();

        match finish_of(&rx1) {
            TokenEvent::Finished {
                ticket,
                tokens,
                ttft,
                ..
            } => {
                assert_eq!(ticket, t1.id);
                assert_eq!(tokens.len(), 8);
                assert!(ttft.is_some());
            }
            other => panic!("expected Finished, got {other:?}"),
        }
        match finish_of(&rx2) {
            TokenEvent::Finished { ticket, tokens, .. } => {
                assert_eq!(ticket, t2.id);
                assert_eq!(tokens.len(), 4);
            }
            other => panic!("expected Finished, got {other:?}"),
        }

        let engine = h.shutdown();
        assert_eq!(engine.metrics.online_completed, 2);
        assert_eq!(engine.metrics.offline_completed, 1);
        engine.kv.check_invariants().unwrap();
    }

    #[test]
    fn streaming_delivers_every_token_in_order() {
        let h = handle();
        let (t, rx) = h
            .submit_streaming(SubmitSpec::online(PromptSpec::sim(100, None), 6))
            .unwrap();
        let mut seen = Vec::new();
        loop {
            let ev = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let terminal = ev.is_terminal();
            seen.push(ev);
            if terminal {
                break;
            }
        }
        assert!(matches!(seen.first(), Some(TokenEvent::FirstToken { .. })));
        assert!(matches!(seen.last(), Some(TokenEvent::Finished { .. })));
        assert_eq!(seen.len(), 7, "first + 5 tokens + finished: {seen:?}");
        assert!(seen.iter().all(|e| e.ticket() == t.id));
        let _ = h.shutdown();
    }

    #[test]
    fn dropped_receiver_cancels_the_request() {
        // Regression for the pre-serve bug: an online completion whose
        // client receiver was dropped used to be sent into a dead channel
        // while the request kept consuming KV/decode slots to completion.
        let h = handle();
        // Effectively unbounded generation: can only end via cancel.
        let (victim, rx) =
            h.submit_streaming(SubmitSpec::online(PromptSpec::sim(64, None), 1_000_000))
            .unwrap();
        // Wait until it is actually streaming, then abandon it.
        let first = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(matches!(first, TokenEvent::FirstToken { .. }));
        drop(rx);

        // A second request proves the engine keeps serving others.
        let (t2, rx2) = h
            .submit_streaming(SubmitSpec::online(PromptSpec::sim(128, None), 4))
            .unwrap();
        match finish_of(&rx2) {
            TokenEvent::Finished { ticket, tokens, .. } => {
                assert_eq!(ticket, t2.id);
                assert_eq!(tokens.len(), 4);
            }
            other => panic!("expected Finished, got {other:?}"),
        }

        // Give the coordinator a few turns to notice the dead channel.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if h.snapshot().cancelled >= 1 {
                break;
            }
            assert!(Instant::now() < deadline, "victim was never cancelled");
            std::thread::sleep(Duration::from_millis(2));
        }
        let engine = h.shutdown();
        let r = engine.store.get(victim.id);
        assert_eq!(r.state, ReqState::Cancelled);
        assert!(r.generated < 1_000_000, "victim must not run to completion");
        assert!(!r.has_interned_keys(), "interned keys released on cancel");
        assert_eq!(engine.kv.held_blocks(victim.id), 0, "KV released");
        assert_eq!(engine.metrics.cancelled_online, 1);
        assert_eq!(engine.metrics.online_completed, 1);
        engine.kv.check_invariants().unwrap();
    }

    #[test]
    fn unschedulable_request_is_rejected_with_cancelled() {
        // A request larger than the whole KV capacity can never be
        // scheduled; the coordinator must reject it with a terminal event
        // instead of leaving its stream (and any drain) hanging forever.
        let mut cfg = SystemConfig::a100_llama8b();
        cfg.cache.capacity_tokens = 2_000;
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), 4, 0.0);
        let h = spawn(Engine::new(cfg, backend));
        let (t, rx) = h
            .submit_streaming(SubmitSpec::online(PromptSpec::sim(5_000, None), 4))
            .unwrap();
        match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
            TokenEvent::Cancelled { ticket, .. } => assert_eq!(ticket, t.id),
            other => panic!("expected Cancelled, got {other:?}"),
        }
        let engine = h.shutdown();
        assert_eq!(engine.metrics.cancelled_online, 1);
        assert_eq!(engine.metrics.online_completed, 0);
        engine.kv.check_invariants().unwrap();
    }

    #[test]
    fn serve_trait_pump_and_drain() {
        let mut h = handle();
        let t = Serve::submit(&mut h, SubmitSpec::online(PromptSpec::sim(150, None), 3)).unwrap();
        h.submit_detached(SubmitSpec::offline(PromptSpec::sim(600, None), 8))
            .unwrap();
        let mut evs: Vec<TokenEvent> = Vec::new();
        h.drain(&mut evs).unwrap();
        let finishes = evs
            .iter()
            .filter(|e| matches!(e, TokenEvent::Finished { .. }))
            .count();
        assert_eq!(finishes, 2, "both tickets finish through pump: {evs:?}");
        assert!(evs.iter().any(|e| e.ticket() == t.id));
        let snap = h.snapshot();
        assert_eq!(snap.online_completed + snap.offline_completed, 2);
        let engine = h.shutdown();
        engine.kv.check_invariants().unwrap();
    }
}

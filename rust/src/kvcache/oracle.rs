//! Non-incremental reference KV manager (the pre-PR implementation, kept
//! verbatim as an oracle — same pattern as `scheduler::OracleScheduler`).
//!
//! [`OracleKvManager`] keeps the eviction order in one global
//! `BTreeSet<(prio, lat, id)>`, re-scans the priority-0 prefix on **every**
//! `availability()` call, walks the free table for `eviction_preview`, and
//! resolves prefix hits three times per `allocate` (peek, free-table pass,
//! pin) — exactly what `KvManager` did before the bucketed victim index.
//! It exists so that
//!
//!   * `rust/tests/kv_equivalence.rs` can assert the bucketed manager is a
//!     bit-exact drop-in (victim sequence, availability tuples, key
//!     samples, churn deltas, stats), and
//!   * `benches/microbench.rs` can record the pre-PR cost in the same
//!     `BENCH_PR5.json` it records the bucketed path in (the `--gate-kv`
//!     before/after pair comes from one harness run).
//!
//! Do not optimize this module; its value is being the slow, obviously
//! correct baseline.

// lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
use std::collections::{BTreeSet, HashMap, HashSet};

use super::manager::{lat_bits, prio_bits, Availability, CacheStats, EvictionPolicy, KvOp};
use super::BlockId;
use crate::core::{RequestId, TaskClass};

#[derive(Clone, Debug)]
struct BlockMeta {
    key: Option<u128>,
    ref_count: u32,
    last_access: f64,
    class: TaskClass,
    finished: bool,
    /// Sort key currently registered in the free table.
    table_key: Option<(u64, u64)>,
}

impl BlockMeta {
    fn fresh() -> Self {
        BlockMeta {
            key: None,
            ref_count: 0,
            last_access: 0.0,
            class: TaskClass::Offline,
            finished: true,
            table_key: None,
        }
    }
}

/// Clone of the pre-PR [`super::KvManager`] (global `BTreeSet` free table,
/// scan-per-call availability, triple-lookup allocate, SipHash key maps).
pub struct OracleKvManager {
    block_size: usize,
    capacity: usize,
    policy: EvictionPolicy,
    blocks: Vec<BlockMeta>,
    free_list: Vec<BlockId>,
    cached: HashMap<u128, BlockId>, // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
    cached_sorted: BTreeSet<u128>,
    track_churn: bool,
    churn_added: HashSet<u128>, // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
    churn_removed: HashSet<u128>, // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
    /// Eviction order: (priority_bits, lat_bits, id). Only ref_count == 0
    /// blocks live here.
    free_table: BTreeSet<(u64, u64, BlockId)>,
    future_refs: HashMap<u128, u32>, // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
    owned: HashMap<RequestId, Vec<BlockId>>, // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
    reserve_blocks: usize,
    pub stats: CacheStats,
}

impl OracleKvManager {
    pub fn new(capacity_blocks: usize, block_size: usize, policy: EvictionPolicy) -> Self {
        OracleKvManager {
            block_size,
            capacity: capacity_blocks,
            policy,
            blocks: vec![BlockMeta::fresh(); capacity_blocks],
            free_list: (0..capacity_blocks as BlockId).rev().collect(),
            cached: HashMap::new(), // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
            cached_sorted: BTreeSet::new(),
            track_churn: false,
            churn_added: HashSet::new(), // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
            churn_removed: HashSet::new(), // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
            free_table: BTreeSet::new(),
            future_refs: HashMap::new(), // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
            owned: HashMap::new(), // lint: allow-std-map(oracle keeps the pre-PR-5 std maps verbatim)
            reserve_blocks: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    pub fn set_reserve_tokens(&mut self, tokens: usize) {
        self.reserve_blocks = tokens.div_ceil(self.block_size).min(self.capacity);
    }

    pub fn reserve_blocks(&self) -> usize {
        self.reserve_blocks
    }

    pub fn register_future(&mut self, keys: &[u128]) {
        for &k in keys {
            *self.future_refs.entry(k).or_insert(0) += 1;
            if let Some(&b) = self.cached.get(&k) {
                self.requeue_free(b);
            }
        }
    }

    pub fn unregister_future(&mut self, keys: &[u128]) {
        for &k in keys {
            if let Some(rc) = self.future_refs.get_mut(&k) {
                *rc -= 1;
                if *rc == 0 {
                    self.future_refs.remove(&k);
                }
            }
            if let Some(&b) = self.cached.get(&k) {
                self.requeue_free(b);
            }
        }
    }

    #[doc(hidden)]
    pub fn future_ref_count(&self, key: u128) -> u32 {
        self.future_refs.get(&key).copied().unwrap_or(0)
    }

    pub fn peek_prefix(&self, keys: &[u128]) -> usize {
        keys.iter()
            .take_while(|k| self.cached.contains_key(k))
            .count()
    }

    fn cache_insert(&mut self, k: u128, b: BlockId) {
        if let Some(old_b) = self.cached.insert(k, b) {
            if old_b != b {
                self.stats.superseded += 1;
            }
            return;
        }
        self.cached_sorted.insert(k);
        if self.track_churn && !self.churn_removed.remove(&k) {
            self.churn_added.insert(k);
        }
    }

    fn cache_remove(&mut self, k: u128) {
        if self.cached.remove(&k).is_none() {
            return;
        }
        self.cached_sorted.remove(&k);
        if self.track_churn && !self.churn_added.remove(&k) {
            self.churn_removed.insert(k);
        }
    }

    pub fn cached_key_count(&self) -> usize {
        self.cached.len()
    }

    pub fn enable_key_churn(&mut self) {
        self.track_churn = true;
    }

    pub fn take_key_churn(&mut self) -> Option<(Vec<u128>, Vec<u128>)> {
        if !self.track_churn {
            return None;
        }
        let mut added: Vec<u128> = self.churn_added.drain().collect();
        let mut removed: Vec<u128> = self.churn_removed.drain().collect();
        added.sort_unstable();
        removed.sort_unstable();
        Some((added, removed))
    }

    pub fn cached_key_sample(&self, cap: usize) -> Vec<u128> {
        self.cached_sorted.iter().copied().take(cap).collect()
    }

    /// Pre-PR `availability`: the priority-0 prefix of the free table is
    /// re-scanned on every call — the cost the bucketed manager's
    /// incremental counters remove.
    pub fn availability(&self) -> Availability {
        let evictable = self.free_table.len();
        let useless = self
            .free_table
            .iter()
            .take_while(|&&(p, _, _)| p == 0)
            .count();
        Availability {
            free: self.free_list.len(),
            evictable,
            evictable_useless: useless,
            reserve: self.reserve_blocks,
        }
    }

    pub fn eviction_preview(&self, n: usize) -> u64 {
        let mut punished = 0u64;
        for (i, &(_, _, b)) in self.free_table.iter().enumerate() {
            if i >= n {
                break;
            }
            if self.block_rc(b) > 0 {
                punished += self.block_size as u64;
            }
        }
        punished
    }

    fn block_rc(&self, b: BlockId) -> u32 {
        self.blocks[b as usize]
            .key
            .and_then(|k| self.future_refs.get(&k).copied())
            .unwrap_or(0)
    }

    fn priority(&self, b: BlockId) -> f64 {
        if self.policy == EvictionPolicy::Lru {
            return 0.0;
        }
        let meta = &self.blocks[b as usize];
        let rc = self.block_rc(b);
        match (meta.class, rc) {
            (TaskClass::Offline, rc) if rc > 0 => rc as f64,
            (TaskClass::Online, _) if meta.finished => 0.5,
            (TaskClass::Online, rc) if rc > 0 => rc as f64,
            _ => 0.0,
        }
    }

    fn requeue_free(&mut self, b: BlockId) {
        let old = self.blocks[b as usize].table_key.take();
        if let Some((p, t)) = old {
            self.free_table.remove(&(p, t, b));
        }
        if self.blocks[b as usize].ref_count == 0 && self.blocks[b as usize].key.is_some() {
            let key = (
                prio_bits(self.priority(b)),
                lat_bits(self.blocks[b as usize].last_access),
                b,
            );
            self.free_table.insert(key);
            self.blocks[b as usize].table_key = Some((key.0, key.1));
        }
    }

    fn remove_from_free_table(&mut self, b: BlockId) {
        if let Some((p, t)) = self.blocks[b as usize].table_key.take() {
            self.free_table.remove(&(p, t, b));
        }
    }

    fn evict_one(&mut self) -> Option<BlockId> {
        let &(p, t, b) = self.free_table.iter().next()?;
        self.free_table.remove(&(p, t, b));
        let key = {
            let meta = &mut self.blocks[b as usize];
            meta.table_key = None;
            meta.key.take()
        };
        self.stats.evictions += 1;
        if let Some(k) = key {
            self.cache_remove(k);
            if self.future_refs.get(&k).copied().unwrap_or(0) > 0 {
                self.stats.useful_evictions += 1;
                self.stats.punished_tokens += self.block_size as u64;
            }
        }
        Some(b)
    }

    /// Evict the next victim and return its block to the free list — the
    /// observable victim-order hook the equivalence tests compare.
    #[doc(hidden)]
    pub fn pop_victim(&mut self) -> Option<BlockId> {
        let b = self.evict_one()?;
        self.free_list.push(b);
        Some(b)
    }

    fn take_block(&mut self) -> Option<BlockId> {
        if let Some(b) = self.free_list.pop() {
            return Some(b);
        }
        self.evict_one()
    }

    /// Pre-PR `allocate`: resolves prefix hits three times (peek, the
    /// hits-from-free pass, the pin re-get) — the cost the bucketed
    /// manager's single resolve pass removes.
    pub fn allocate(
        &mut self,
        req: RequestId,
        class: TaskClass,
        keys: &[u128],
        total_blocks: usize,
        now: f64,
    ) -> Option<usize> {
        debug_assert!(!self.owned.contains_key(&req), "request already holds blocks");
        let hit_blocks = self.peek_prefix(&keys[..keys.len().min(total_blocks)]);
        self.stats.lookup_blocks += keys.len().min(total_blocks) as u64;
        self.stats.hit_blocks += hit_blocks as u64;

        let fresh_needed = total_blocks - hit_blocks;
        let hits_from_free = keys
            .iter()
            .take(hit_blocks)
            .filter(|k| {
                let b = self.cached[k];
                self.blocks[b as usize].ref_count == 0
            })
            .count();
        let avail = self.availability();
        let allowed = match class {
            TaskClass::Online => avail.for_online(),
            TaskClass::Offline => avail.for_offline(),
        };
        if fresh_needed + hits_from_free > allowed {
            return None;
        }

        let mut held = Vec::with_capacity(total_blocks);
        for &k in keys.iter().take(hit_blocks) {
            // lint: allow-unwrap(peek_prefix resolved these keys moments ago)
            let b = *self.cached.get(&k).expect("peeked block vanished");
            let meta = &mut self.blocks[b as usize];
            meta.ref_count += 1;
            meta.last_access = now;
            meta.finished = false;
            self.remove_from_free_table(b);
            held.push(b);
        }
        self.stats.saved_tokens += (hit_blocks * self.block_size) as u64;

        for i in hit_blocks..total_blocks {
            // lint: allow-unwrap(feasibility was checked against availability() above)
            let b = self.take_block().expect("availability check lied");
            let key = keys.get(i).copied();
            {
                let meta = &mut self.blocks[b as usize];
                meta.ref_count = 1;
                meta.last_access = now;
                meta.class = class;
                meta.finished = false;
                meta.key = key;
                meta.table_key = None;
            }
            if let Some(k) = key {
                self.cache_insert(k, b);
            }
            held.push(b);
        }
        self.owned.insert(req, held);
        Some(hit_blocks * self.block_size)
    }

    pub fn grow(&mut self, req: RequestId, class: TaskClass, n: usize, now: f64) -> bool {
        let avail = self.availability();
        let allowed = match class {
            TaskClass::Online => avail.for_online(),
            TaskClass::Offline => avail.for_offline(),
        };
        if n > allowed {
            return false;
        }
        for _ in 0..n {
            // lint: allow-unwrap(feasibility was checked against availability() above)
            let b = self.take_block().expect("availability check lied");
            let meta = &mut self.blocks[b as usize];
            meta.ref_count = 1;
            meta.last_access = now;
            meta.class = class;
            meta.finished = false;
            meta.key = None;
            meta.table_key = None;
            self.owned.entry(req).or_default().push(b);
        }
        true
    }

    pub fn touch(&mut self, req: RequestId, now: f64) {
        if let Some(blocks) = self.owned.get(&req).cloned() {
            for b in blocks {
                self.blocks[b as usize].last_access = now;
            }
        }
    }

    pub fn held_blocks(&self, req: RequestId) -> usize {
        self.owned.get(&req).map_or(0, |v| v.len())
    }

    pub fn occupied_blocks(&self) -> usize {
        self.capacity - self.free_list.len() - self.free_table.len()
    }

    pub fn release(&mut self, req: RequestId, finished: bool) {
        let Some(blocks) = self.owned.remove(&req) else {
            return;
        };
        for b in blocks {
            let meta = &mut self.blocks[b as usize];
            debug_assert!(meta.ref_count > 0);
            meta.ref_count -= 1;
            if meta.ref_count > 0 {
                continue;
            }
            meta.finished = finished;
            if meta.key.is_some() {
                self.requeue_free(b);
            } else {
                self.free_list.push(b);
            }
        }
    }

    pub fn flush_cache(&mut self) {
        while self.pop_victim().is_some() {}
    }

    pub fn resident_tokens(&self) -> usize {
        (self.capacity - self.free_list.len()) * self.block_size
    }

    pub fn occupancy_breakdown(&self) -> (usize, usize, usize, usize) {
        let running = self.occupied_blocks();
        let mut cached_online = 0;
        let mut cached_offline = 0;
        for &(_, _, b) in &self.free_table {
            match self.blocks[b as usize].class {
                TaskClass::Online => cached_online += 1,
                TaskClass::Offline => cached_offline += 1,
            }
        }
        (running, cached_online, cached_offline, self.free_list.len())
    }

    /// Replay one recorded [`KvOp`] (see `KvManager::enable_op_log`).
    #[doc(hidden)]
    pub fn apply_op(&mut self, op: &KvOp) {
        match op {
            KvOp::Allocate { req, class, keys, total_blocks, now } => {
                let _ = self.allocate(*req, *class, keys, *total_blocks, *now);
            }
            KvOp::Grow { req, class, n, now } => {
                let _ = self.grow(*req, *class, *n, *now);
            }
            KvOp::Touch { req, now } => self.touch(*req, *now),
            KvOp::Release { req, finished } => self.release(*req, *finished),
            KvOp::RegisterFuture { keys } => self.register_future(keys),
            KvOp::UnregisterFuture { keys } => self.unregister_future(keys),
            KvOp::SetReserveTokens { tokens } => self.set_reserve_tokens(*tokens),
            KvOp::FlushCache => self.flush_cache(),
        }
    }

    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut refs = vec![0u32; self.capacity];
        for v in self.owned.values() {
            for &b in v {
                refs[b as usize] += 1;
            }
        }
        for (i, meta) in self.blocks.iter().enumerate() {
            if meta.ref_count != refs[i] {
                return Err(format!(
                    "block {i}: ref_count {} != owners {}",
                    meta.ref_count, refs[i]
                ));
            }
            if meta.ref_count > 0 && meta.table_key.is_some() {
                return Err(format!("block {i}: pinned but in free table"));
            }
        }
        let in_table = self.free_table.len();
        let in_free = self.free_list.len();
        let pinned = self.blocks.iter().filter(|m| m.ref_count > 0).count();
        if in_table + in_free + pinned != self.capacity {
            return Err(format!(
                "partition broken: table {in_table} + free {in_free} + pinned {pinned} != {}",
                self.capacity
            ));
        }
        for (&k, &b) in &self.cached {
            if self.blocks[b as usize].key != Some(k) {
                return Err(format!("cached index stale for key {k:x}"));
            }
        }
        if self.cached_sorted.len() != self.cached.len()
            || self.cached.keys().any(|k| !self.cached_sorted.contains(k))
        {
            return Err("sorted key mirror diverged from the cached index".to_string());
        }
        for &(p, t, b) in &self.free_table {
            if self.blocks[b as usize].table_key != Some((p, t)) {
                return Err(format!("free table stale for block {b}"));
            }
        }
        Ok(())
    }
}

//! The KV cache manager implementation. See module docs in `mod.rs`.

use std::collections::{BTreeSet, HashMap, HashSet};

use super::BlockId;
use crate::core::{RequestId, TaskClass};

/// LRU (vLLM default) or the paper's task-aware priority scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    Lru,
    TaskAware,
}

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    /// Prefix-lookup block counts (Fig. 9's hit-ratio numerator/denominator).
    pub lookup_blocks: u64,
    pub hit_blocks: u64,
    /// Total evictions, and evictions of blocks that were still useful
    /// (RC > 0) — each of those is a future re-prefill (the paper's
    /// Punishment, Eq. 2).
    pub evictions: u64,
    pub useful_evictions: u64,
    /// Tokens of punishment incurred (evicted-but-needed blocks x block_size).
    pub punished_tokens: u64,
    /// Tokens of prefill saved through prefix hits.
    pub saved_tokens: u64,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        if self.lookup_blocks == 0 {
            0.0
        } else {
            self.hit_blocks as f64 / self.lookup_blocks as f64
        }
    }
}

#[derive(Clone, Debug)]
struct BlockMeta {
    /// Content key (chain hash); present while the block is reusable.
    key: Option<u128>,
    /// Requests currently holding the block (running/scheduled).
    ref_count: u32,
    /// Last access time (LAT column of Fig. 5).
    last_access: f64,
    /// Task class that produced the block.
    class: TaskClass,
    /// True once no unfinished request owns the content.
    finished: bool,
    /// Sort key currently registered in the free table.
    table_key: Option<(u64, u64)>,
}

impl BlockMeta {
    fn fresh() -> Self {
        BlockMeta {
            key: None,
            ref_count: 0,
            last_access: 0.0,
            class: TaskClass::Offline,
            finished: true,
            table_key: None,
        }
    }
}

/// Allocation headroom snapshot used by the scheduler's feasibility checks.
#[derive(Clone, Copy, Debug, Default)]
pub struct Availability {
    /// Never-used or fully-released blocks.
    pub free: usize,
    /// Cached blocks that can be evicted (free-table size).
    pub evictable: usize,
    /// Evictable blocks that are useless (priority 0: finished offline,
    /// RC = 0) — evicting them costs nothing.
    pub evictable_useless: usize,
    /// Current reserve (threshold headroom) in blocks.
    pub reserve: usize,
}

impl Availability {
    /// Blocks an *offline* allocation may claim (must respect the reserve).
    pub fn for_offline(&self) -> usize {
        (self.free + self.evictable).saturating_sub(self.reserve)
    }

    /// Blocks an *online* allocation may claim.
    pub fn for_online(&self) -> usize {
        self.free + self.evictable
    }
}

pub struct KvManager {
    block_size: usize,
    capacity: usize,
    policy: EvictionPolicy,
    blocks: Vec<BlockMeta>,
    /// Blocks never allocated or whose content was dropped.
    free_list: Vec<BlockId>,
    /// Content key -> resident block (the APC prefix index).
    cached: HashMap<u128, BlockId>,
    /// Sorted mirror of `cached`'s key set, maintained incrementally so
    /// prefix-summary publication never rebuilds-and-sorts the whole set.
    cached_sorted: BTreeSet<u128>,
    /// Key churn since the last `take_key_churn` drain (delta-digest
    /// protocol; only tracked once `enable_key_churn` was called, so
    /// standalone engines pay nothing and leak nothing).
    track_churn: bool,
    churn_added: HashSet<u128>,
    churn_removed: HashSet<u128>,
    /// Eviction order: (priority_bits, lat_bits, id). Only ref_count == 0
    /// blocks live here.
    free_table: BTreeSet<(u64, u64, BlockId)>,
    /// Future reference counts per content key (offline requests that are
    /// registered and unfinished, including currently running ones).
    future_refs: HashMap<u128, u32>,
    /// Blocks held per request.
    owned: HashMap<RequestId, Vec<BlockId>>,
    /// Threshold headroom in blocks (set from the memory predictor).
    reserve_blocks: usize,
    pub stats: CacheStats,
}

fn prio_bits(p: f64) -> u64 {
    debug_assert!(p >= 0.0);
    p.to_bits()
}

fn lat_bits(t: f64) -> u64 {
    debug_assert!(t >= 0.0);
    t.to_bits()
}

impl KvManager {
    pub fn new(capacity_blocks: usize, block_size: usize, policy: EvictionPolicy) -> Self {
        KvManager {
            block_size,
            capacity: capacity_blocks,
            policy,
            blocks: vec![BlockMeta::fresh(); capacity_blocks],
            free_list: (0..capacity_blocks as BlockId).rev().collect(),
            cached: HashMap::new(),
            cached_sorted: BTreeSet::new(),
            track_churn: false,
            churn_added: HashSet::new(),
            churn_removed: HashSet::new(),
            free_table: BTreeSet::new(),
            future_refs: HashMap::new(),
            owned: HashMap::new(),
            reserve_blocks: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    /// Set the burst-headroom threshold (tokens). Called by the engine each
    /// predictor period; ignored under policies without thresholds.
    pub fn set_reserve_tokens(&mut self, tokens: usize) {
        self.reserve_blocks = tokens.div_ceil(self.block_size).min(self.capacity);
    }

    pub fn reserve_blocks(&self) -> usize {
        self.reserve_blocks
    }

    /// Register future interest of an offline request in its content keys
    /// (entering the pool / being admitted). RC drives eviction priority.
    pub fn register_future(&mut self, keys: &[u128]) {
        for &k in keys {
            *self.future_refs.entry(k).or_insert(0) += 1;
            if let Some(&b) = self.cached.get(&k) {
                self.requeue_free(b);
            }
        }
    }

    /// Remove future interest (request finished or cancelled).
    pub fn unregister_future(&mut self, keys: &[u128]) {
        for &k in keys {
            if let Some(rc) = self.future_refs.get_mut(&k) {
                *rc -= 1;
                if *rc == 0 {
                    self.future_refs.remove(&k);
                }
            }
            if let Some(&b) = self.cached.get(&k) {
                self.requeue_free(b);
            }
        }
    }

    /// Outstanding future interest registered on a content key (test hook:
    /// cancellation must drop a withdrawn request's contribution).
    #[doc(hidden)]
    pub fn future_ref_count(&self, key: u128) -> u32 {
        self.future_refs.get(&key).copied().unwrap_or(0)
    }

    /// How many leading blocks of `keys` are resident right now (without
    /// pinning them). Free for planning; does not touch stats.
    pub fn peek_prefix(&self, keys: &[u128]) -> usize {
        keys.iter()
            .take_while(|k| self.cached.contains_key(k))
            .count()
    }

    /// Register a key as resident. Mirrors `cached` into the sorted set and
    /// the churn log; a duplicate insert (stale block superseded by a fresh
    /// one for the same content) overwrites the mapping like the plain
    /// `HashMap` insert always did, without touching mirror or churn — the
    /// key was resident before and stays resident.
    fn cache_insert(&mut self, k: u128, b: BlockId) {
        if self.cached.insert(k, b).is_some() {
            return;
        }
        self.cached_sorted.insert(k);
        if self.track_churn && !self.churn_removed.remove(&k) {
            self.churn_added.insert(k);
        }
    }

    /// Drop a key from the resident set (eviction).
    fn cache_remove(&mut self, k: u128) {
        if self.cached.remove(&k).is_none() {
            return;
        }
        self.cached_sorted.remove(&k);
        if self.track_churn && !self.churn_added.remove(&k) {
            self.churn_removed.insert(k);
        }
    }

    /// Number of distinct resident content keys.
    pub fn cached_key_count(&self) -> usize {
        self.cached.len()
    }

    /// Start tracking key churn for the delta-digest protocol (cluster
    /// replicas call this once; standalone engines never pay for it).
    pub fn enable_key_churn(&mut self) {
        self.track_churn = true;
    }

    /// Drain the net key churn since the last drain: `(added, removed)`,
    /// each sorted ascending and mutually disjoint (a key cached and
    /// evicted within one window cancels out). Returns `None` when churn
    /// tracking is disabled. Applying `removed` then `added` to the
    /// previous full summary reproduces `cached_key_sample(usize::MAX)`
    /// exactly — the equivalence property test pins this down.
    pub fn take_key_churn(&mut self) -> Option<(Vec<u128>, Vec<u128>)> {
        if !self.track_churn {
            return None;
        }
        let mut added: Vec<u128> = self.churn_added.drain().collect();
        let mut removed: Vec<u128> = self.churn_removed.drain().collect();
        added.sort_unstable();
        removed.sort_unstable();
        Some((added, removed))
    }

    /// Content keys of all resident (pinned or reusable) blocks — the
    /// prefix summary a cluster replica publishes to the router's radix
    /// index. Chain-hashed keys commit to their whole prefix, so a flat key
    /// set is enough for the router to walk cached prefixes remotely.
    ///
    /// Served from the incrementally maintained sorted mirror: O(cap)
    /// copy, no rebuild, no sort. `cap` bounds the digest size; when the
    /// cache holds more keys the sample is the smallest `cap` keys —
    /// deterministic, and identical to what the old rebuild-and-sort
    /// returned. Numeric key order is unrelated to chain-prefix order, so
    /// truncation can break leading chains and degrade remote
    /// affinity-depth walks — size `cap` to the cache (`capacity_blocks`,
    /// the `ClusterConfig::new` default) unless digest memory genuinely
    /// needs bounding below that.
    pub fn cached_key_sample(&self, cap: usize) -> Vec<u128> {
        self.cached_sorted.iter().copied().take(cap).collect()
    }

    /// Pre-PR reference implementation of [`Self::cached_key_sample`]
    /// (rebuild from the hash index, sort only when truncating) — kept, like
    /// `scheduler::OracleScheduler`, so the microbench baseline records the
    /// genuine before-cost in the same run as the after-cost. Not for
    /// production use: the result set is identical but the order of the
    /// untruncated sample is nondeterministic.
    #[doc(hidden)]
    pub fn cached_key_sample_rebuild(&self, cap: usize) -> Vec<u128> {
        if self.cached.len() <= cap {
            self.cached.keys().copied().collect()
        } else {
            let mut keys: Vec<u128> = self.cached.keys().copied().collect();
            keys.sort_unstable();
            keys.truncate(cap);
            keys
        }
    }

    /// Current allocation headroom.
    pub fn availability(&self) -> Availability {
        let evictable = self.free_table.len();
        // Priority-0 prefix of the table: entries with prio bits == 0.
        let useless = self
            .free_table
            .iter()
            .take_while(|&&(p, _, _)| p == 0)
            .count();
        Availability {
            free: self.free_list.len(),
            evictable,
            evictable_useless: useless,
            reserve: self.reserve_blocks,
        }
    }

    /// Preview the punishment (tokens needing future recomputation) of
    /// evicting the next `n` victims, without mutating anything.
    pub fn eviction_preview(&self, n: usize) -> u64 {
        let mut punished = 0u64;
        for (i, &(_, _, b)) in self.free_table.iter().enumerate() {
            if i >= n {
                break;
            }
            if self.block_rc(b) > 0 {
                punished += self.block_size as u64;
            }
        }
        punished
    }

    fn block_rc(&self, b: BlockId) -> u32 {
        self.blocks[b as usize]
            .key
            .and_then(|k| self.future_refs.get(&k).copied())
            .unwrap_or(0)
    }

    /// Paper §4.2 priority of a *free* (ref_count == 0) block.
    fn priority(&self, b: BlockId) -> f64 {
        if self.policy == EvictionPolicy::Lru {
            return 0.0; // pure LAT ordering
        }
        let meta = &self.blocks[b as usize];
        let rc = self.block_rc(b);
        match (meta.class, rc) {
            (TaskClass::Offline, rc) if rc > 0 => rc as f64,
            (TaskClass::Online, _) if meta.finished => 0.5,
            (TaskClass::Online, rc) if rc > 0 => rc as f64, // preempted-online content
            _ => 0.0,
        }
    }

    fn requeue_free(&mut self, b: BlockId) {
        let old = self.blocks[b as usize].table_key.take();
        if let Some((p, t)) = old {
            self.free_table.remove(&(p, t, b));
        }
        if self.blocks[b as usize].ref_count == 0 && self.blocks[b as usize].key.is_some() {
            let key = (
                prio_bits(self.priority(b)),
                lat_bits(self.blocks[b as usize].last_access),
                b,
            );
            self.free_table.insert(key);
            self.blocks[b as usize].table_key = Some((key.0, key.1));
        }
    }

    fn remove_from_free_table(&mut self, b: BlockId) {
        if let Some((p, t)) = self.blocks[b as usize].table_key.take() {
            self.free_table.remove(&(p, t, b));
        }
    }

    /// Evict the lowest-priority free block; returns its id. Records
    /// punishment if the block was still wanted.
    fn evict_one(&mut self) -> Option<BlockId> {
        let &(p, t, b) = self.free_table.iter().next()?;
        self.free_table.remove(&(p, t, b));
        let key = {
            let meta = &mut self.blocks[b as usize];
            meta.table_key = None;
            meta.key.take()
        };
        self.stats.evictions += 1;
        if let Some(k) = key {
            self.cache_remove(k);
            if self.future_refs.get(&k).copied().unwrap_or(0) > 0 {
                self.stats.useful_evictions += 1;
                self.stats.punished_tokens += self.block_size as u64;
            }
        }
        Some(b)
    }

    /// Take one physical block (free list first, then eviction).
    fn take_block(&mut self) -> Option<BlockId> {
        if let Some(b) = self.free_list.pop() {
            return Some(b);
        }
        self.evict_one()
    }

    /// Pin the longest cached prefix of `keys` for `req` and allocate fresh
    /// blocks so that `total_blocks` are held. Returns the number of tokens
    /// already covered by cache hits (the fast-forward), or None if memory
    /// does not permit (the caller should have checked availability; None
    /// only happens under races with the reserve rule).
    ///
    /// `class` drives both the reserve rule and the metadata of the fresh
    /// blocks; `keys` may be shorter than `total_blocks` for generated
    /// (decode) blocks, which are unshareable and get no content key.
    pub fn allocate(
        &mut self,
        req: RequestId,
        class: TaskClass,
        keys: &[u128],
        total_blocks: usize,
        now: f64,
    ) -> Option<usize> {
        debug_assert!(!self.owned.contains_key(&req), "request already holds blocks");
        // 1. Count prefix hits (pin later, after feasibility is known).
        let hit_blocks = self.peek_prefix(&keys[..keys.len().min(total_blocks)]);
        self.stats.lookup_blocks += keys.len().min(total_blocks) as u64;
        self.stats.hit_blocks += hit_blocks as u64;

        let fresh_needed = total_blocks - hit_blocks;
        // Hit blocks sitting in the free table leave it when pinned, so
        // they consume allocatable headroom exactly like fresh blocks
        // (this also makes the reserve threshold apply to reactivations).
        let hits_from_free = keys
            .iter()
            .take(hit_blocks)
            .filter(|k| {
                let b = self.cached[k];
                self.blocks[b as usize].ref_count == 0
            })
            .count();
        let avail = self.availability();
        let allowed = match class {
            TaskClass::Online => avail.for_online(),
            TaskClass::Offline => avail.for_offline(),
        };
        if fresh_needed + hits_from_free > allowed {
            // Keep lookups counted; hits unused.
            return None;
        }

        let mut held = Vec::with_capacity(total_blocks);
        // 2. Pin hits.
        for &k in keys.iter().take(hit_blocks) {
            let b = *self.cached.get(&k).expect("peeked block vanished");
            let meta = &mut self.blocks[b as usize];
            meta.ref_count += 1;
            meta.last_access = now;
            meta.finished = false;
            self.remove_from_free_table(b);
            held.push(b);
        }
        self.stats.saved_tokens += (hit_blocks * self.block_size) as u64;

        // 3. Fresh blocks (keyed for prompt region, unkeyed past `keys`).
        for i in hit_blocks..total_blocks {
            let b = self.take_block().expect("availability check lied");
            let key = keys.get(i).copied();
            {
                let meta = &mut self.blocks[b as usize];
                meta.ref_count = 1;
                meta.last_access = now;
                meta.class = class;
                meta.finished = false;
                meta.key = key;
                meta.table_key = None;
            }
            if let Some(k) = key {
                self.cache_insert(k, b);
            }
            held.push(b);
        }
        self.owned.insert(req, held);
        Some(hit_blocks * self.block_size)
    }

    /// Append `n` fresh unshareable blocks to a running request (decode
    /// growth). Returns false if memory does not permit.
    pub fn grow(&mut self, req: RequestId, class: TaskClass, n: usize, now: f64) -> bool {
        let avail = self.availability();
        let allowed = match class {
            TaskClass::Online => avail.for_online(),
            TaskClass::Offline => avail.for_offline(),
        };
        if n > allowed {
            return false;
        }
        for _ in 0..n {
            let b = self.take_block().expect("availability check lied");
            let meta = &mut self.blocks[b as usize];
            meta.ref_count = 1;
            meta.last_access = now;
            meta.class = class;
            meta.finished = false;
            meta.key = None;
            meta.table_key = None;
            self.owned.entry(req).or_default().push(b);
        }
        true
    }

    /// Touch all blocks of `req` (scheduled this iteration).
    pub fn touch(&mut self, req: RequestId, now: f64) {
        if let Some(blocks) = self.owned.get(&req).cloned() {
            for b in blocks {
                self.blocks[b as usize].last_access = now;
            }
        }
    }

    /// Number of blocks currently held by `req`.
    pub fn held_blocks(&self, req: RequestId) -> usize {
        self.owned.get(&req).map_or(0, |v| v.len())
    }

    /// Total blocks held by running requests.
    pub fn occupied_blocks(&self) -> usize {
        self.capacity - self.free_list.len() - self.free_table.len()
    }

    /// Release a request's blocks (preemption or completion). Content-keyed
    /// blocks go to the free table (still reusable); unkeyed blocks return
    /// to the free list.
    pub fn release(&mut self, req: RequestId, finished: bool) {
        let Some(blocks) = self.owned.remove(&req) else {
            return;
        };
        for b in blocks {
            let meta = &mut self.blocks[b as usize];
            debug_assert!(meta.ref_count > 0);
            meta.ref_count -= 1;
            if meta.ref_count > 0 {
                continue; // still pinned by a sharing sibling
            }
            meta.finished = finished;
            if meta.key.is_some() {
                self.requeue_free(b);
            } else {
                self.free_list.push(b);
            }
        }
    }

    /// Drop every cached (free-table) block — test/bench helper for
    /// measuring cold-cache behaviour.
    pub fn flush_cache(&mut self) {
        while self.evict_one().map(|b| self.free_list.push(b)).is_some() {}
    }

    /// Tokens of KV currently resident (running + reusable cache).
    pub fn resident_tokens(&self) -> usize {
        (self.capacity - self.free_list.len()) * self.block_size
    }

    /// Memory-occupancy breakdown for Fig. 10: (running, cached_online,
    /// cached_offline, free) in blocks.
    pub fn occupancy_breakdown(&self) -> (usize, usize, usize, usize) {
        let running = self.occupied_blocks();
        let mut cached_online = 0;
        let mut cached_offline = 0;
        for &(_, _, b) in &self.free_table {
            match self.blocks[b as usize].class {
                TaskClass::Online => cached_online += 1,
                TaskClass::Offline => cached_offline += 1,
            }
        }
        (running, cached_online, cached_offline, self.free_list.len())
    }

    /// Invariant checker used by property tests.
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        let owned_total: usize = self.owned.values().map(|v| v.len()).sum();
        let mut refs = vec![0u32; self.capacity];
        for v in self.owned.values() {
            for &b in v {
                refs[b as usize] += 1;
            }
        }
        for (i, meta) in self.blocks.iter().enumerate() {
            if meta.ref_count != refs[i] {
                return Err(format!(
                    "block {i}: ref_count {} != owners {}",
                    meta.ref_count, refs[i]
                ));
            }
            if meta.ref_count > 0 && meta.table_key.is_some() {
                return Err(format!("block {i}: pinned but in free table"));
            }
        }
        let in_table = self.free_table.len();
        let in_free = self.free_list.len();
        // Every block is free, in the table, or pinned (shared pins may
        // make pinned-block count < owned_total).
        let pinned = self.blocks.iter().filter(|m| m.ref_count > 0).count();
        if in_table + in_free + pinned != self.capacity {
            return Err(format!(
                "partition broken: table {in_table} + free {in_free} + pinned {pinned} != {}",
                self.capacity
            ));
        }
        for (&k, &b) in &self.cached {
            if self.blocks[b as usize].key != Some(k) {
                return Err(format!("cached index stale for key {k:x}"));
            }
        }
        if self.cached_sorted.len() != self.cached.len()
            || self.cached.keys().any(|k| !self.cached_sorted.contains(k))
        {
            return Err("sorted key mirror diverged from the cached index".to_string());
        }
        for &(p, t, b) in &self.free_table {
            if self.blocks[b as usize].table_key != Some((p, t)) {
                return Err(format!("free table stale for block {b}"));
            }
        }
        let _ = owned_total;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BS: usize = 16;

    fn keys(owner: RequestId, n: usize) -> Vec<u128> {
        // distinct unshared keys
        (0..n).map(|i| ((owner as u128) << 64) | i as u128).collect()
    }

    fn shared_keys(group: u128, n: usize) -> Vec<u128> {
        (0..n).map(|i| (group << 96) | i as u128).collect()
    }

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        let ks = keys(1, 4);
        let ff = m.allocate(1, TaskClass::Offline, &ks, 4, 0.0).unwrap();
        assert_eq!(ff, 0);
        assert_eq!(m.held_blocks(1), 4);
        assert_eq!(m.occupied_blocks(), 4);
        m.check_invariants().unwrap();
        m.release(1, true);
        assert_eq!(m.occupied_blocks(), 0);
        assert_eq!(m.availability().evictable, 4);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prefix_hit_fast_forwards() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        let shared = shared_keys(7, 3);
        m.register_future(&shared); // sibling interest keeps blocks alive
        m.allocate(1, TaskClass::Offline, &shared, 3, 0.0).unwrap();
        m.release(1, true);
        // Second request with same prefix + 2 private blocks.
        let mut ks2 = shared.clone();
        ks2.extend(keys(2, 2));
        let ff = m.allocate(2, TaskClass::Offline, &ks2, 5, 1.0).unwrap();
        assert_eq!(ff, 3 * BS, "3 shared blocks fast-forwarded");
        assert!(m.stats.hit_ratio() > 0.0);
        assert_eq!(m.stats.saved_tokens, (3 * BS) as u64);
        m.check_invariants().unwrap();
    }

    #[test]
    fn eviction_order_respects_task_priority() {
        let mut m = KvManager::new(4, BS, EvictionPolicy::TaskAware);
        // Offline block with future interest (rc=1).
        let off = keys(1, 1);
        m.register_future(&off);
        m.allocate(1, TaskClass::Offline, &off, 1, 0.0).unwrap();
        m.release(1, false);
        // Finished online block (later LAT — LRU would evict offline first anyway,
        // so make online *older* to prove priority dominates LAT).
        let on = keys(2, 1);
        m.allocate(2, TaskClass::Online, &on, 1, 0.5).unwrap();
        m.release(2, true);
        // Finished offline rc=0 (newest).
        let dead = keys(3, 1);
        m.allocate(3, TaskClass::Offline, &dead, 1, 5.0).unwrap();
        m.release(3, true);

        // Demand 3 fresh blocks: eviction order must be dead (p0),
        // online-finished (p0.5), offline-rc1 (p1).
        m.allocate(4, TaskClass::Online, &keys(4, 4), 4, 6.0).unwrap();
        assert_eq!(m.stats.evictions, 3);
        assert_eq!(m.stats.useful_evictions, 1, "only the rc=1 block was useful");
        assert_eq!(m.stats.punished_tokens, BS as u64);
        m.check_invariants().unwrap();
    }

    #[test]
    fn lru_ignores_priority() {
        let mut m = KvManager::new(2, BS, EvictionPolicy::Lru);
        let off = keys(1, 1);
        m.register_future(&off); // rc=1 — would be protected under TaskAware
        m.allocate(1, TaskClass::Offline, &off, 1, 0.0).unwrap();
        m.release(1, false);
        let on = keys(2, 1);
        m.allocate(2, TaskClass::Online, &on, 1, 1.0).unwrap();
        m.release(2, true);
        // One fresh block needed: LRU evicts oldest = the useful offline block.
        m.allocate(3, TaskClass::Online, &keys(3, 1), 1, 2.0).unwrap();
        assert_eq!(m.stats.useful_evictions, 1);
    }

    #[test]
    fn task_aware_protects_useful_block() {
        let mut m = KvManager::new(2, BS, EvictionPolicy::TaskAware);
        let off = keys(1, 1);
        m.register_future(&off);
        m.allocate(1, TaskClass::Offline, &off, 1, 0.0).unwrap();
        m.release(1, false);
        let on = keys(2, 1);
        m.allocate(2, TaskClass::Online, &on, 1, 1.0).unwrap();
        m.release(2, true);
        m.allocate(3, TaskClass::Online, &keys(3, 1), 1, 2.0).unwrap();
        assert_eq!(
            m.stats.useful_evictions, 0,
            "task-aware policy must evict the finished online block instead"
        );
        // The offline block is still hittable.
        assert_eq!(m.peek_prefix(&off), 1);
    }

    #[test]
    fn reserve_blocks_offline_not_online() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        m.set_reserve_tokens(4 * BS);
        assert_eq!(m.availability().for_offline(), 6);
        assert_eq!(m.availability().for_online(), 10);
        // Offline may take 6, not 7.
        assert!(m.allocate(1, TaskClass::Offline, &keys(1, 7), 7, 0.0).is_none());
        assert!(m.allocate(1, TaskClass::Offline, &keys(1, 6), 6, 0.0).is_some());
        // Online can use the reserve.
        assert!(m.allocate(2, TaskClass::Online, &keys(2, 4), 4, 0.0).is_some());
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_pin_survives_single_release() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        let shared = shared_keys(9, 2);
        m.register_future(&shared);
        m.register_future(&shared);
        m.allocate(1, TaskClass::Offline, &shared, 2, 0.0).unwrap();
        let ff = m.allocate(2, TaskClass::Offline, &shared, 2, 0.1).unwrap();
        assert_eq!(ff, 2 * BS);
        m.release(1, true);
        m.unregister_future(&shared);
        // Request 2 still holds the blocks.
        assert_eq!(m.held_blocks(2), 2);
        assert_eq!(m.occupied_blocks(), 2);
        m.check_invariants().unwrap();
        m.release(2, true);
        m.unregister_future(&shared);
        assert_eq!(m.occupied_blocks(), 0);
        m.check_invariants().unwrap();
    }

    #[test]
    fn grow_appends_unkeyed() {
        let mut m = KvManager::new(5, BS, EvictionPolicy::TaskAware);
        m.allocate(1, TaskClass::Online, &keys(1, 2), 2, 0.0).unwrap();
        assert!(m.grow(1, TaskClass::Online, 2, 1.0));
        assert_eq!(m.held_blocks(1), 4);
        m.release(1, true);
        // Unkeyed decode blocks return to the free list, keyed ones to cache.
        let a = m.availability();
        assert_eq!(a.evictable, 2);
        assert_eq!(a.free, 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn eviction_preview_counts_useful() {
        let mut m = KvManager::new(4, BS, EvictionPolicy::TaskAware);
        let off = keys(1, 2);
        m.register_future(&off);
        m.allocate(1, TaskClass::Offline, &off, 2, 0.0).unwrap();
        m.release(1, false);
        let dead = keys(2, 2);
        m.allocate(2, TaskClass::Offline, &dead, 2, 1.0).unwrap();
        m.release(2, true);
        // Victims in order: 2 dead blocks (p0), then 2 useful (rc=1).
        assert_eq!(m.eviction_preview(2), 0);
        assert_eq!(m.eviction_preview(3), BS as u64);
        assert_eq!(m.eviction_preview(4), 2 * BS as u64);
    }

    #[test]
    fn flush_cache_empties_table() {
        let mut m = KvManager::new(8, BS, EvictionPolicy::TaskAware);
        m.allocate(1, TaskClass::Offline, &keys(1, 3), 3, 0.0).unwrap();
        m.release(1, true);
        m.flush_cache();
        let a = m.availability();
        assert_eq!(a.evictable, 0);
        assert_eq!(a.free, 8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn key_churn_tracks_net_delta() {
        let mut m = KvManager::new(4, BS, EvictionPolicy::TaskAware);
        m.enable_key_churn();
        assert_eq!(m.take_key_churn(), Some((vec![], vec![])));
        let a = keys(1, 2);
        m.allocate(1, TaskClass::Offline, &a, 2, 0.0).unwrap();
        m.release(1, true);
        let (added, removed) = m.take_key_churn().unwrap();
        assert_eq!(added.len(), 2);
        assert!(removed.is_empty());
        assert_eq!(added, m.cached_key_sample(usize::MAX));
        // Fill the cache so fresh allocations evict the old keys.
        let b = keys(2, 4);
        m.allocate(2, TaskClass::Offline, &b, 4, 1.0).unwrap();
        let (added, removed) = m.take_key_churn().unwrap();
        assert_eq!(added.len(), 4, "new keys reported");
        assert_eq!(removed.len(), 2, "evicted keys reported");
        let mut expect = a.clone();
        expect.sort_unstable();
        assert_eq!(removed, expect);
        // Cached-then-evicted within one window cancels to nothing.
        m.release(2, true);
        m.flush_cache();
        let c = keys(3, 1);
        m.allocate(3, TaskClass::Offline, &c, 1, 2.0).unwrap();
        m.release(3, true);
        m.flush_cache();
        let (added, removed) = m.take_key_churn().unwrap();
        assert!(added.is_empty(), "transient key must cancel: {added:?}");
        // b's keys were resident at the last drain and are now gone.
        let mut expect = b.clone();
        expect.sort_unstable();
        assert_eq!(removed, expect);
        m.check_invariants().unwrap();
    }

    #[test]
    fn sample_served_sorted_from_mirror() {
        let mut m = KvManager::new(8, BS, EvictionPolicy::TaskAware);
        let ks = keys(5, 6);
        m.allocate(5, TaskClass::Offline, &ks, 6, 0.0).unwrap();
        let mut expect = ks.clone();
        expect.sort_unstable();
        assert_eq!(m.cached_key_sample(usize::MAX), expect);
        assert_eq!(m.cached_key_sample(3), &expect[..3], "cap takes smallest keys");
        assert_eq!(m.cached_key_count(), 6);
        // The pre-PR reference path returns the same key set (the bench
        // baseline depends on the two being interchangeable).
        let mut rebuilt = m.cached_key_sample_rebuild(usize::MAX);
        rebuilt.sort_unstable();
        assert_eq!(rebuilt, m.cached_key_sample(usize::MAX));
        assert_eq!(m.cached_key_sample_rebuild(3), &expect[..3]);
        m.check_invariants().unwrap();
    }

    #[test]
    fn rc_change_requeues_priority() {
        let mut m = KvManager::new(2, BS, EvictionPolicy::TaskAware);
        let a = keys(1, 1);
        let b = keys(2, 1);
        m.register_future(&a);
        m.allocate(1, TaskClass::Offline, &a, 1, 0.0).unwrap();
        m.release(1, false);
        m.allocate(2, TaskClass::Offline, &b, 1, 1.0).unwrap();
        m.release(2, false);
        m.register_future(&b); // b now rc=1, a rc=1 — tie broken by LAT (a older)
        m.unregister_future(&a); // a drops to rc=0 => evicted first despite age
        m.allocate(3, TaskClass::Online, &keys(3, 1), 1, 2.0).unwrap();
        assert_eq!(m.peek_prefix(&b), 1, "b must survive");
        assert_eq!(m.peek_prefix(&a), 0, "a (rc=0) must be the victim");
    }
}

//! The KV cache manager implementation. See module docs in `mod.rs`.
//!
//! Hot-path data structures (PR 5): the eviction order lives in a
//! **bucketed victim index** — one bucket per discrete priority value
//! (0, 0.5, future-RC 1, 2, ...), each an intrusive doubly-linked list of
//! blocks ordered by (last-access, id). Steady-state operations are O(1)
//! amortized: releases append at the tail (time is monotonic), eviction
//! pops the head of the lowest non-empty bucket, and RC-driven requeues
//! splice between buckets. `availability()` reads incrementally maintained
//! counters instead of scanning the table, and `eviction_preview` sums
//! per-bucket punished counters. The eviction order is bit-exact with the
//! pre-PR global `BTreeSet<(prio, lat, id)>` — [`super::OracleKvManager`]
//! keeps that implementation verbatim and `rust/tests/kv_equivalence.rs`
//! pins the equivalence.

use std::cell::Cell;
use std::collections::BTreeSet;

use super::BlockId;
use crate::core::{RequestId, TaskClass};
use crate::utils::hash::{FxHashMap, FxHashSet};

/// LRU (vLLM default) or the paper's task-aware priority scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    Lru,
    TaskAware,
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Prefix-lookup block counts (Fig. 9's hit-ratio numerator/denominator).
    pub lookup_blocks: u64,
    pub hit_blocks: u64,
    /// Total evictions, and evictions of blocks that were still useful
    /// (RC > 0) — each of those is a future re-prefill (the paper's
    /// Punishment, Eq. 2).
    pub evictions: u64,
    pub useful_evictions: u64,
    /// Tokens of punishment incurred (evicted-but-needed blocks x block_size).
    pub punished_tokens: u64,
    /// Tokens of prefill saved through prefix hits.
    pub saved_tokens: u64,
    /// Cached entries superseded by a fresh block for the same content key
    /// (the old block lingers as a zombie holder until its RC drains).
    pub superseded: u64,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        if self.lookup_blocks == 0 {
            0.0
        } else {
            self.hit_blocks as f64 / self.lookup_blocks as f64
        }
    }
}

/// One public KV-manager mutation, recorded when the op log is enabled
/// (`enable_op_log`). The equivalence tests replay a real engine run's log
/// into both [`KvManager`] and [`super::OracleKvManager`] and compare every
/// observable along the way.
#[derive(Clone, Debug, PartialEq)]
pub enum KvOp {
    Allocate {
        req: RequestId,
        class: TaskClass,
        keys: Vec<u128>,
        total_blocks: usize,
        now: f64,
    },
    Grow {
        req: RequestId,
        class: TaskClass,
        n: usize,
        now: f64,
    },
    Touch { req: RequestId, now: f64 },
    Release { req: RequestId, finished: bool },
    RegisterFuture { keys: Vec<u128> },
    UnregisterFuture { keys: Vec<u128> },
    SetReserveTokens { tokens: usize },
    FlushCache,
}

#[derive(Clone, Debug)]
struct BlockMeta {
    /// Content key (chain hash); present while the block is reusable.
    key: Option<u128>,
    /// Requests currently holding the block (running/scheduled).
    ref_count: u32,
    /// Last access time (LAT column of Fig. 5).
    last_access: f64,
    /// Task class that produced the block.
    class: TaskClass,
    /// True once no unfinished request owns the content.
    finished: bool,
    /// Sort key currently registered in the victim index
    /// (priority bits, LAT bits); `None` when not evictable.
    table_key: Option<(u64, u64)>,
}

impl BlockMeta {
    fn fresh() -> Self {
        BlockMeta {
            key: None,
            ref_count: 0,
            last_access: 0.0,
            class: TaskClass::Offline,
            finished: true,
            table_key: None,
        }
    }
}

/// Allocation headroom snapshot used by the scheduler's feasibility checks.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Availability {
    /// Never-used or fully-released blocks.
    pub free: usize,
    /// Cached blocks that can be evicted (victim-index size).
    pub evictable: usize,
    /// Evictable blocks that are useless (priority 0: finished offline,
    /// RC = 0) — evicting them costs nothing.
    pub evictable_useless: usize,
    /// Current reserve (threshold headroom) in blocks.
    pub reserve: usize,
}

impl Availability {
    /// Blocks an *offline* allocation may claim (must respect the reserve).
    pub fn for_offline(&self) -> usize {
        (self.free + self.evictable).saturating_sub(self.reserve)
    }

    /// Blocks an *online* allocation may claim.
    pub fn for_online(&self) -> usize {
        self.free + self.evictable
    }
}

pub(crate) fn prio_bits(p: f64) -> u64 {
    debug_assert!(p >= 0.0);
    p.to_bits()
}

pub(crate) fn lat_bits(t: f64) -> u64 {
    debug_assert!(t >= 0.0);
    t.to_bits()
}

/// Bucket index of the hyper-shared overflow: RC values past the clamp
/// collapse into one bucket (ordered internally by the full sort key), so
/// the dense bucket vector stays bounded instead of growing O(max RC ever
/// observed) when thousands of pooled requests share one prefix.
const OVERFLOW_BUCKET: usize = 130;

/// Bucket slot for one discrete priority value. Priorities are 0.0
/// (bucket 0), 0.5 (bucket 1), and future-RC `n >= 1` (bucket `n + 1`,
/// clamped to [`OVERFLOW_BUCKET`]) — the mapping is monotone in the
/// priority, so ascending bucket order is ascending `(prio_bits, ...)`
/// order; within a bucket the insert walk orders by the full
/// (prio, LAT, id) key, which is what makes the overflow bucket (the only
/// one holding mixed priorities) exact.
fn bucket_of_bits(p_bits: u64) -> usize {
    let p = f64::from_bits(p_bits);
    let raw = if p == 0.0 {
        0
    } else if p == 0.5 {
        1
    } else {
        p as usize + 1
    };
    raw.min(OVERFLOW_BUCKET)
}

const NIL: BlockId = BlockId::MAX;

/// Intrusive list node, one per physical block (dense, id-indexed).
#[derive(Clone, Copy, Debug)]
struct VictimNode {
    prev: BlockId,
    next: BlockId,
    /// Bucket index while linked.
    bucket: u32,
    /// Priority bits while linked (uniform per bucket except in the
    /// overflow bucket, where it carries the within-bucket sort).
    prio: u64,
    /// LAT bits while linked (the within-bucket sort key, ties on id).
    lat: u64,
    /// Whether this block counted into its bucket's punished counter.
    punished: bool,
}

impl VictimNode {
    fn fresh() -> Self {
        VictimNode {
            prev: NIL,
            next: NIL,
            bucket: u32::MAX,
            prio: 0,
            lat: 0,
            punished: false,
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct VictimBucket {
    head: BlockId,
    tail: BlockId,
    len: usize,
    /// Blocks in this bucket whose content has future interest (RC > 0):
    /// evicting one incurs the paper's punishment.
    punished: usize,
}

impl VictimBucket {
    fn empty() -> Self {
        VictimBucket {
            head: NIL,
            tail: NIL,
            len: 0,
            punished: 0,
        }
    }
}

/// The bucketed victim index. Replaces the global
/// `BTreeSet<(prio, lat, id)>` free table: same iteration order
/// (ascending priority bucket, then ascending (LAT, id) within a bucket),
/// O(1) amortized maintenance.
struct VictimIndex {
    nodes: Vec<VictimNode>,
    buckets: Vec<VictimBucket>,
    /// Indices of non-empty buckets, ascending. The bucket vector is
    /// sized by the largest RC ever observed and never shrinks, so
    /// `front`/`eviction_preview` walk this set instead of scanning empty
    /// slots; it only changes on empty<->non-empty transitions
    /// (O(log distinct-priorities), and the low buckets transition
    /// rarely in steady state).
    occupied: BTreeSet<u32>,
    len: usize,
}

impl VictimIndex {
    fn new(capacity: usize) -> Self {
        VictimIndex {
            nodes: vec![VictimNode::fresh(); capacity],
            buckets: Vec::new(),
            occupied: BTreeSet::new(),
            len: 0,
        }
    }

    /// Insert `b` into bucket `bi` keeping (prio, lat, id) ascending — the
    /// prio component is uniform everywhere but the overflow bucket. Walks
    /// from *both ends* in lockstep and takes whichever resolves first, so
    /// both realistic access patterns are O(1): releases (monotonic time)
    /// append at the tail, and RC churn on the coldest cached content
    /// prepends at the head. Only a mid-bucket insert pays
    /// O(distance-to-nearer-end).
    fn link(&mut self, b: BlockId, bi: usize, prio: u64, lat: u64, punished: bool) {
        if self.buckets.len() <= bi {
            self.buckets.resize(bi + 1, VictimBucket::empty());
        }
        // `after` = the last node ordered before `b` (NIL: insert at head).
        let mut back = self.buckets[bi].tail;
        let mut fwd = self.buckets[bi].head;
        let after = loop {
            if back == NIL {
                break NIL; // walked past the head: b precedes everything
            }
            let nb = &self.nodes[back as usize];
            if (nb.prio, nb.lat, back) <= (prio, lat, b) {
                break back;
            }
            back = nb.prev;
            // `fwd` is always valid here: it only advances past nodes
            // ordered before `b`, and if every node were, the tail check
            // above would already have resolved.
            let nf = &self.nodes[fwd as usize];
            if (nf.prio, nf.lat, fwd) > (prio, lat, b) {
                break nf.prev;
            }
            fwd = nf.next;
        };
        let next = if after == NIL {
            self.buckets[bi].head
        } else {
            self.nodes[after as usize].next
        };
        {
            let node = &mut self.nodes[b as usize];
            node.prev = after;
            node.next = next;
            node.bucket = bi as u32;
            node.prio = prio;
            node.lat = lat;
            node.punished = punished;
        }
        if after == NIL {
            self.buckets[bi].head = b;
        } else {
            self.nodes[after as usize].next = b;
        }
        if next == NIL {
            self.buckets[bi].tail = b;
        } else {
            self.nodes[next as usize].prev = b;
        }
        if self.buckets[bi].len == 0 {
            self.occupied.insert(bi as u32);
        }
        self.buckets[bi].len += 1;
        self.buckets[bi].punished += punished as usize;
        self.len += 1;
    }

    fn unlink(&mut self, b: BlockId) {
        let (prev, next, bi, punished) = {
            let n = &self.nodes[b as usize];
            (n.prev, n.next, n.bucket as usize, n.punished)
        };
        if prev == NIL {
            self.buckets[bi].head = next;
        } else {
            self.nodes[prev as usize].next = next;
        }
        if next == NIL {
            self.buckets[bi].tail = prev;
        } else {
            self.nodes[next as usize].prev = prev;
        }
        self.buckets[bi].len -= 1;
        if self.buckets[bi].len == 0 {
            self.occupied.remove(&(bi as u32));
        }
        self.buckets[bi].punished -= punished as usize;
        self.len -= 1;
        self.nodes[b as usize] = VictimNode::fresh();
    }

    /// Flip a linked block's punished flag in place (an RC edge that does
    /// not change the block's priority value, e.g. future interest landing
    /// on an online-finished block, or any RC edge under LRU).
    fn set_punished(&mut self, b: BlockId, punished: bool) {
        let node = &mut self.nodes[b as usize];
        if node.punished == punished {
            return;
        }
        let bi = node.bucket as usize;
        node.punished = punished;
        if punished {
            self.buckets[bi].punished += 1;
        } else {
            self.buckets[bi].punished -= 1;
        }
    }

    /// Global eviction head: head of the lowest non-empty bucket — one
    /// ordered-set lookup, regardless of how many (possibly empty)
    /// priority slots the bucket vector has accumulated.
    fn front(&self) -> Option<BlockId> {
        self.occupied.first().map(|&bi| self.buckets[bi as usize].head)
    }
}

pub struct KvManager {
    block_size: usize,
    capacity: usize,
    policy: EvictionPolicy,
    blocks: Vec<BlockMeta>,
    /// Blocks never allocated or whose content was dropped.
    free_list: Vec<BlockId>,
    /// Content key -> resident block (the APC prefix index).
    cached: FxHashMap<u128, BlockId>,
    /// Sorted mirror of `cached`'s key set, maintained incrementally so
    /// prefix-summary publication never rebuilds-and-sorts the whole set.
    cached_sorted: BTreeSet<u128>,
    /// Key churn since the last `take_key_churn` drain (delta-digest
    /// protocol; only tracked once `enable_key_churn` was called, so
    /// standalone engines pay nothing and leak nothing).
    track_churn: bool,
    churn_added: FxHashSet<u128>,
    churn_removed: FxHashSet<u128>,
    /// Eviction order (see [`VictimIndex`]). Only ref_count == 0 blocks
    /// live here.
    victims: VictimIndex,
    /// Future reference counts per content key (offline requests that are
    /// registered and unfinished, including currently running ones).
    future_refs: FxHashMap<u128, u32>,
    /// Zombie holders: blocks whose `key` is `Some(k)` while `cached[k]`
    /// points elsewhere (or nowhere). The pre-PR code leaves such blocks
    /// in the free table untouched — they arise when a fresh block
    /// supersedes a resident key after a partial-prefix eviction, or when
    /// evicting a zombie drops the current holder's mapping. They matter
    /// only because `eviction_preview`'s punished counters must keep
    /// seeing their **live** RC: every RC edge on `k` refreshes the
    /// linked holders listed here (the oracle reads live RC per victim,
    /// so a stale flag would break bit-exactness).
    stale_holders: FxHashMap<u128, Vec<BlockId>>,
    /// Blocks held per request.
    owned: FxHashMap<RequestId, Vec<BlockId>>,
    /// Threshold headroom in blocks (set from the memory predictor).
    reserve_blocks: usize,
    /// Reusable hit-resolution buffer for `allocate`'s single pass.
    hit_scratch: Vec<BlockId>,
    /// `availability()` invocations since construction (regression hook
    /// alongside `Request::key_compute_count` / `Engine::step_alloc_growth`:
    /// the scheduler's trial path must take one snapshot per admission
    /// round, not one per candidate).
    availability_calls: Cell<u64>,
    /// Mutation log for oracle replay (`enable_op_log`); `None` costs
    /// nothing.
    op_log: Option<Vec<KvOp>>,
    pub stats: CacheStats,
}

impl KvManager {
    pub fn new(capacity_blocks: usize, block_size: usize, policy: EvictionPolicy) -> Self {
        KvManager {
            block_size,
            capacity: capacity_blocks,
            policy,
            blocks: vec![BlockMeta::fresh(); capacity_blocks],
            free_list: (0..capacity_blocks as BlockId).rev().collect(),
            cached: FxHashMap::default(),
            cached_sorted: BTreeSet::new(),
            track_churn: false,
            churn_added: FxHashSet::default(),
            churn_removed: FxHashSet::default(),
            victims: VictimIndex::new(capacity_blocks),
            future_refs: FxHashMap::default(),
            stale_holders: FxHashMap::default(),
            owned: FxHashMap::default(),
            reserve_blocks: 0,
            hit_scratch: Vec::new(),
            availability_calls: Cell::new(0),
            op_log: None,
            stats: CacheStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    /// Set the burst-headroom threshold (tokens). Called by the engine each
    /// predictor period; ignored under policies without thresholds.
    pub fn set_reserve_tokens(&mut self, tokens: usize) {
        if let Some(log) = &mut self.op_log {
            log.push(KvOp::SetReserveTokens { tokens });
        }
        self.reserve_blocks = tokens.div_ceil(self.block_size).min(self.capacity);
    }

    pub fn reserve_blocks(&self) -> usize {
        self.reserve_blocks
    }

    /// Start recording every public mutation (oracle-replay equivalence
    /// tests). Not for production: the log grows without bound until
    /// drained.
    #[doc(hidden)]
    pub fn enable_op_log(&mut self) {
        self.op_log = Some(Vec::new());
    }

    /// Drain the recorded mutation log.
    #[doc(hidden)]
    pub fn take_op_log(&mut self) -> Vec<KvOp> {
        self.op_log.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// `availability()` call count since construction (regression hook).
    pub fn availability_calls(&self) -> u64 {
        self.availability_calls.get()
    }

    /// Register future interest of an offline request in its content keys
    /// (entering the pool / being admitted). RC drives eviction priority.
    pub fn register_future(&mut self, keys: &[u128]) {
        if self.op_log.is_some() {
            self.log_op(KvOp::RegisterFuture { keys: keys.to_vec() });
        }
        for &k in keys {
            *self.future_refs.entry(k).or_insert(0) += 1;
            if let Some(&b) = self.cached.get(&k) {
                self.requeue_free(b);
            }
            self.refresh_stale_punished(k);
        }
    }

    /// Remove future interest (request finished or cancelled).
    pub fn unregister_future(&mut self, keys: &[u128]) {
        if self.op_log.is_some() {
            self.log_op(KvOp::UnregisterFuture { keys: keys.to_vec() });
        }
        for &k in keys {
            if let Some(rc) = self.future_refs.get_mut(&k) {
                *rc -= 1;
                if *rc == 0 {
                    self.future_refs.remove(&k);
                }
            }
            if let Some(&b) = self.cached.get(&k) {
                self.requeue_free(b);
            }
            self.refresh_stale_punished(k);
        }
    }

    /// Propagate an RC edge on `k` to linked zombie holders (see
    /// `stale_holders`): their frozen table position matches the pre-PR
    /// order (which never requeued them either), but their punished flags
    /// must track the live RC. No-op (one hash miss) when `k` has none.
    fn refresh_stale_punished(&mut self, k: u128) {
        let Some(holders) = self.stale_holders.remove(&k) else {
            return;
        };
        let punished = self.future_refs.get(&k).copied().unwrap_or(0) > 0;
        for &h in &holders {
            if self.blocks[h as usize].table_key.is_some() {
                self.victims.set_punished(h, punished);
            }
        }
        self.stale_holders.insert(k, holders);
    }

    fn log_op(&mut self, op: KvOp) {
        if let Some(log) = &mut self.op_log {
            log.push(op);
        }
    }

    /// Outstanding future interest registered on a content key (test hook:
    /// cancellation must drop a withdrawn request's contribution).
    #[doc(hidden)]
    pub fn future_ref_count(&self, key: u128) -> u32 {
        self.future_refs.get(&key).copied().unwrap_or(0)
    }

    /// How many leading blocks of `keys` are resident right now (without
    /// pinning them). Free for planning; does not touch stats.
    pub fn peek_prefix(&self, keys: &[u128]) -> usize {
        keys.iter()
            .take_while(|k| self.cached.contains_key(k))
            .count()
    }

    /// Register a key as resident. Mirrors `cached` into the sorted set and
    /// the churn log; a duplicate insert (stale block superseded by a fresh
    /// one for the same content) overwrites the mapping like the plain
    /// map insert always did, without touching mirror or churn — the
    /// key was resident before and stays resident. The superseded block
    /// becomes a zombie holder (see `stale_holders`) so later RC edges
    /// still reach its punished flag.
    fn cache_insert(&mut self, k: u128, b: BlockId) {
        if let Some(old_b) = self.cached.insert(k, b) {
            if old_b != b {
                self.stats.superseded += 1;
                self.stale_holders.entry(k).or_default().push(old_b);
            }
            return;
        }
        self.cached_sorted.insert(k);
        if self.track_churn && !self.churn_removed.remove(&k) {
            self.churn_added.insert(k);
        }
    }

    /// Drop a key from the resident set (eviction).
    fn cache_remove(&mut self, k: u128) {
        if self.cached.remove(&k).is_none() {
            return;
        }
        self.cached_sorted.remove(&k);
        if self.track_churn && !self.churn_added.remove(&k) {
            self.churn_removed.insert(k);
        }
    }

    /// Number of distinct resident content keys.
    pub fn cached_key_count(&self) -> usize {
        self.cached.len()
    }

    /// Start tracking key churn for the delta-digest protocol (cluster
    /// replicas call this once; standalone engines never pay for it).
    pub fn enable_key_churn(&mut self) {
        self.track_churn = true;
    }

    /// Drain the net key churn since the last drain: `(added, removed)`,
    /// each sorted ascending and mutually disjoint (a key cached and
    /// evicted within one window cancels out). Returns `None` when churn
    /// tracking is disabled. Applying `removed` then `added` to the
    /// previous full summary reproduces `cached_key_sample(usize::MAX)`
    /// exactly — the equivalence property test pins this down.
    pub fn take_key_churn(&mut self) -> Option<(Vec<u128>, Vec<u128>)> {
        if !self.track_churn {
            return None;
        }
        let mut added: Vec<u128> = self.churn_added.drain().collect();
        let mut removed: Vec<u128> = self.churn_removed.drain().collect();
        added.sort_unstable();
        removed.sort_unstable();
        Some((added, removed))
    }

    /// Content keys of all resident (pinned or reusable) blocks — the
    /// prefix summary a cluster replica publishes to the router's radix
    /// index. Chain-hashed keys commit to their whole prefix, so a flat key
    /// set is enough for the router to walk cached prefixes remotely.
    ///
    /// Served from the incrementally maintained sorted mirror: O(cap)
    /// copy, no rebuild, no sort. `cap` bounds the digest size; when the
    /// cache holds more keys the sample is the smallest `cap` keys —
    /// deterministic, and identical to what the old rebuild-and-sort
    /// returned. Numeric key order is unrelated to chain-prefix order, so
    /// truncation can break leading chains and degrade remote
    /// affinity-depth walks — size `cap` to the cache (`capacity_blocks`,
    /// the `ClusterConfig::new` default) unless digest memory genuinely
    /// needs bounding below that. `ClusterSim::new` logs a warning when a
    /// config opts into truncation.
    pub fn cached_key_sample(&self, cap: usize) -> Vec<u128> {
        self.cached_sorted.iter().copied().take(cap).collect()
    }

    /// Pre-PR-2 reference implementation of [`Self::cached_key_sample`]
    /// (rebuild from the hash index, sort only when truncating) — kept, like
    /// [`super::OracleKvManager`], so the microbench baseline records the
    /// genuine before-cost in the same run as the after-cost. Not for
    /// production use: the result set is identical but the order of the
    /// untruncated sample follows hash-map iteration order.
    #[doc(hidden)]
    pub fn cached_key_sample_rebuild(&self, cap: usize) -> Vec<u128> {
        if self.cached.len() <= cap {
            self.cached.keys().copied().collect()
        } else {
            let mut keys: Vec<u128> = self.cached.keys().copied().collect();
            keys.sort_unstable();
            keys.truncate(cap);
            keys
        }
    }

    /// Current allocation headroom. O(1): every field is a maintained
    /// counter — the scheduler may call this on every trial for free
    /// (`availability_calls` counts invocations for regression tests).
    pub fn availability(&self) -> Availability {
        self.availability_calls.set(self.availability_calls.get() + 1);
        Availability {
            free: self.free_list.len(),
            evictable: self.victims.len,
            // Priority-0 blocks are exactly bucket 0.
            evictable_useless: self.victims.buckets.first().map_or(0, |bk| bk.len),
            reserve: self.reserve_blocks,
        }
    }

    /// Preview the punishment (tokens needing future recomputation) of
    /// evicting the next `n` victims, without mutating anything. Whole
    /// buckets are answered from their punished counters; only a bucket cut
    /// mid-way by `n` — and only when it holds a mix of punished and
    /// unpunished blocks (possible for the online-finished bucket and under
    /// LRU) — walks its head prefix.
    pub fn eviction_preview(&self, n: usize) -> u64 {
        let mut punished = 0usize;
        let mut left = n;
        for &bi in &self.victims.occupied {
            let bk = &self.victims.buckets[bi as usize];
            if left == 0 {
                break;
            }
            if bk.len <= left {
                punished += bk.punished;
                left -= bk.len;
            } else {
                punished += if bk.punished == 0 {
                    0
                } else if bk.punished == bk.len {
                    left
                } else {
                    let mut cnt = 0usize;
                    let mut cur = bk.head;
                    for _ in 0..left {
                        let node = &self.victims.nodes[cur as usize];
                        cnt += node.punished as usize;
                        cur = node.next;
                    }
                    cnt
                };
                left = 0;
            }
        }
        punished as u64 * self.block_size as u64
    }

    fn block_rc(&self, b: BlockId) -> u32 {
        self.blocks[b as usize]
            .key
            .and_then(|k| self.future_refs.get(&k).copied())
            .unwrap_or(0)
    }

    /// Paper §4.2 priority of a *free* (ref_count == 0) block.
    fn priority(&self, b: BlockId) -> f64 {
        if self.policy == EvictionPolicy::Lru {
            return 0.0; // pure LAT ordering
        }
        let meta = &self.blocks[b as usize];
        let rc = self.block_rc(b);
        match (meta.class, rc) {
            (TaskClass::Offline, rc) if rc > 0 => rc as f64,
            (TaskClass::Online, _) if meta.finished => 0.5,
            (TaskClass::Online, rc) if rc > 0 => rc as f64, // preempted-online content
            _ => 0.0,
        }
    }

    // lint: hot-path
    fn requeue_free(&mut self, b: BlockId) {
        let meta = &self.blocks[b as usize];
        let eligible = meta.ref_count == 0 && meta.key.is_some();
        let new_key = if eligible {
            Some((
                prio_bits(self.priority(b)),
                lat_bits(self.blocks[b as usize].last_access),
            ))
        } else {
            None
        };
        let old_key = self.blocks[b as usize].table_key;
        if old_key == new_key {
            // Identical sort key: a BTreeSet remove+reinsert would land in
            // the same position, so the node stays put — but the punished
            // flag may still have flipped (an RC edge that does not move
            // the priority: online-finished blocks, or any block under
            // LRU).
            if new_key.is_some() {
                let p = self.block_rc(b) > 0;
                self.victims.set_punished(b, p);
            }
            return;
        }
        if old_key.is_some() {
            self.victims.unlink(b);
        }
        if let Some((pb, lb)) = new_key {
            let punished = self.block_rc(b) > 0;
            self.victims.link(b, bucket_of_bits(pb), pb, lb, punished);
        }
        self.blocks[b as usize].table_key = new_key;
    }

    fn remove_from_free_table(&mut self, b: BlockId) {
        if self.blocks[b as usize].table_key.take().is_some() {
            self.victims.unlink(b);
        }
    }

    /// Evict the lowest-priority free block; returns its id. Records
    /// punishment if the block was still wanted.
    // lint: hot-path
    fn evict_one(&mut self) -> Option<BlockId> {
        let b = self.victims.front()?;
        self.victims.unlink(b);
        let key = {
            let meta = &mut self.blocks[b as usize];
            meta.table_key = None;
            meta.key.take()
        };
        self.stats.evictions += 1;
        if let Some(k) = key {
            // If the victim was a zombie holder, retire its entry.
            if let Some(holders) = self.stale_holders.get_mut(&k) {
                if let Some(pos) = holders.iter().position(|&h| h == b) {
                    holders.swap_remove(pos);
                }
                if holders.is_empty() {
                    self.stale_holders.remove(&k);
                }
            }
            // The pre-PR code drops the mapping unconditionally, so
            // evicting a zombie un-caches the *current* holder — which
            // thereby becomes a zombie itself (kept verbatim for
            // bit-exactness; the equivalence tests cover the cascade).
            let displaced = self.cached.get(&k).copied();
            self.cache_remove(k);
            if let Some(f) = displaced {
                if f != b {
                    self.stale_holders.entry(k).or_default().push(f);
                }
            }
            if self.future_refs.get(&k).copied().unwrap_or(0) > 0 {
                self.stats.useful_evictions += 1;
                self.stats.punished_tokens += self.block_size as u64;
            }
        }
        Some(b)
    }

    /// Evict the next victim and return its block to the free list — the
    /// observable victim-order hook the equivalence tests compare.
    #[doc(hidden)]
    pub fn pop_victim(&mut self) -> Option<BlockId> {
        let b = self.evict_one()?;
        self.free_list.push(b);
        Some(b)
    }

    /// Take one physical block (free list first, then eviction).
    fn take_block(&mut self) -> Option<BlockId> {
        if let Some(b) = self.free_list.pop() {
            return Some(b);
        }
        self.evict_one()
    }

    /// Pin the longest cached prefix of `keys` for `req` and allocate fresh
    /// blocks so that `total_blocks` are held. Returns the number of tokens
    /// already covered by cache hits (the fast-forward), or None if memory
    /// does not permit (the caller should have checked availability; None
    /// only happens under races with the reserve rule).
    ///
    /// `class` drives both the reserve rule and the metadata of the fresh
    /// blocks; `keys` may be shorter than `total_blocks` for generated
    /// (decode) blocks, which are unshareable and get no content key.
    ///
    /// Hit resolution is a **single pass**: one `cached` lookup per hit key
    /// yields the hit count, the free-table membership tally (reserve
    /// accounting), and the block ids to pin — the pre-PR code resolved
    /// each hit three times (peek, free-table filter, pin re-get).
    // lint: hot-path
    pub fn allocate(
        &mut self,
        req: RequestId,
        class: TaskClass,
        keys: &[u128],
        total_blocks: usize,
        now: f64,
    ) -> Option<usize> {
        debug_assert!(!self.owned.contains_key(&req), "request already holds blocks");
        if self.op_log.is_some() {
            self.log_op(KvOp::Allocate {
                req,
                class,
                // lint: allow-alloc(op log is a test-only recording path; None in production)
                keys: keys.to_vec(),
                total_blocks,
                now,
            });
        }
        let lookup = keys.len().min(total_blocks);
        // 1. Resolve the cached prefix once (pin later, after feasibility
        // is known). Hit blocks sitting in the free table leave it when
        // pinned, so they consume allocatable headroom exactly like fresh
        // blocks (this also makes the reserve threshold apply to
        // reactivations).
        let mut hit_scratch = std::mem::take(&mut self.hit_scratch);
        hit_scratch.clear();
        let mut hits_from_free = 0usize;
        for k in &keys[..lookup] {
            let Some(&b) = self.cached.get(k) else { break };
            if self.blocks[b as usize].ref_count == 0 {
                hits_from_free += 1;
            }
            hit_scratch.push(b);
        }
        let hit_blocks = hit_scratch.len();
        self.stats.lookup_blocks += lookup as u64;
        self.stats.hit_blocks += hit_blocks as u64;

        let fresh_needed = total_blocks - hit_blocks;
        let avail = self.availability();
        let allowed = match class {
            TaskClass::Online => avail.for_online(),
            TaskClass::Offline => avail.for_offline(),
        };
        if fresh_needed + hits_from_free > allowed {
            // Keep lookups counted; hits unused.
            self.hit_scratch = hit_scratch;
            return None;
        }

        let mut held = Vec::with_capacity(total_blocks);
        // 2. Pin hits (ids already resolved).
        for &b in &hit_scratch {
            let meta = &mut self.blocks[b as usize];
            meta.ref_count += 1;
            meta.last_access = now;
            meta.finished = false;
            self.remove_from_free_table(b);
            held.push(b);
        }
        self.hit_scratch = hit_scratch;
        self.stats.saved_tokens += (hit_blocks * self.block_size) as u64;

        // 3. Fresh blocks (keyed for prompt region, unkeyed past `keys`).
        for i in hit_blocks..total_blocks {
            // lint: allow-unwrap(feasibility was checked against availability() above)
            let b = self.take_block().expect("availability check lied");
            let key = keys.get(i).copied();
            {
                let meta = &mut self.blocks[b as usize];
                meta.ref_count = 1;
                meta.last_access = now;
                meta.class = class;
                meta.finished = false;
                meta.key = key;
                meta.table_key = None;
            }
            if let Some(k) = key {
                self.cache_insert(k, b);
            }
            held.push(b);
        }
        self.owned.insert(req, held);
        Some(hit_blocks * self.block_size)
    }

    /// Append `n` fresh unshareable blocks to a running request (decode
    /// growth). Returns false if memory does not permit.
    pub fn grow(&mut self, req: RequestId, class: TaskClass, n: usize, now: f64) -> bool {
        if self.op_log.is_some() {
            self.log_op(KvOp::Grow { req, class, n, now });
        }
        let avail = self.availability();
        let allowed = match class {
            TaskClass::Online => avail.for_online(),
            TaskClass::Offline => avail.for_offline(),
        };
        if n > allowed {
            return false;
        }
        for _ in 0..n {
            // lint: allow-unwrap(feasibility was checked against availability() above)
            let b = self.take_block().expect("availability check lied");
            let meta = &mut self.blocks[b as usize];
            meta.ref_count = 1;
            meta.last_access = now;
            meta.class = class;
            meta.finished = false;
            meta.key = None;
            meta.table_key = None;
            self.owned.entry(req).or_default().push(b);
        }
        true
    }

    /// Touch all blocks of `req` (scheduled this iteration). Held blocks
    /// are pinned (never in the victim index), so no requeue is needed.
    pub fn touch(&mut self, req: RequestId, now: f64) {
        if self.op_log.is_some() {
            self.log_op(KvOp::Touch { req, now });
        }
        if let Some(blocks) = self.owned.get(&req).cloned() {
            for b in blocks {
                self.blocks[b as usize].last_access = now;
            }
        }
    }

    /// Number of blocks currently held by `req`.
    pub fn held_blocks(&self, req: RequestId) -> usize {
        self.owned.get(&req).map_or(0, |v| v.len())
    }

    /// Total blocks held by running requests.
    pub fn occupied_blocks(&self) -> usize {
        self.capacity - self.free_list.len() - self.victims.len
    }

    /// Release a request's blocks (preemption or completion). Content-keyed
    /// blocks go to the victim index (still reusable); unkeyed blocks
    /// return to the free list.
    pub fn release(&mut self, req: RequestId, finished: bool) {
        if self.op_log.is_some() {
            self.log_op(KvOp::Release { req, finished });
        }
        let Some(blocks) = self.owned.remove(&req) else {
            return;
        };
        for b in blocks {
            let meta = &mut self.blocks[b as usize];
            debug_assert!(meta.ref_count > 0);
            meta.ref_count -= 1;
            if meta.ref_count > 0 {
                continue; // still pinned by a sharing sibling
            }
            meta.finished = finished;
            if meta.key.is_some() {
                self.requeue_free(b);
            } else {
                self.free_list.push(b);
            }
        }
    }

    /// Drop every cached (victim-index) block — test/bench helper for
    /// measuring cold-cache behaviour.
    pub fn flush_cache(&mut self) {
        if self.op_log.is_some() {
            self.log_op(KvOp::FlushCache);
        }
        while self.pop_victim().is_some() {}
    }

    /// Tokens of KV currently resident (running + reusable cache).
    pub fn resident_tokens(&self) -> usize {
        (self.capacity - self.free_list.len()) * self.block_size
    }

    /// Memory-occupancy breakdown for Fig. 10: (running, cached_online,
    /// cached_offline, free) in blocks.
    pub fn occupancy_breakdown(&self) -> (usize, usize, usize, usize) {
        let running = self.occupied_blocks();
        let mut cached_online = 0;
        let mut cached_offline = 0;
        for &bi in &self.victims.occupied {
            let bk = &self.victims.buckets[bi as usize];
            let mut cur = bk.head;
            while cur != NIL {
                match self.blocks[cur as usize].class {
                    TaskClass::Online => cached_online += 1,
                    TaskClass::Offline => cached_offline += 1,
                }
                cur = self.victims.nodes[cur as usize].next;
            }
        }
        (running, cached_online, cached_offline, self.free_list.len())
    }

    /// Crash-recovery safety net: release every block whose owner is not
    /// in `live` (sorted or not — membership is a linear probe over a
    /// typically tiny set). In normal operation `Engine::cancel`/`release`
    /// already free per-request state, so this finds nothing; the cluster
    /// recovery path runs it on a harvested corpse so a partially-failed
    /// cancel can never strand pinned blocks on a replica about to leave
    /// the fleet. Returns the number of orphaned requests reclaimed.
    pub fn reclaim_orphans(&mut self, live: &[RequestId]) -> usize {
        let orphans: Vec<RequestId> = self
            .owned
            .keys()
            .copied()
            .filter(|r| !live.contains(r))
            .collect();
        // Sort for deterministic release order (owned is a hash map).
        let mut orphans = orphans;
        orphans.sort_unstable();
        let n = orphans.len();
        for req in orphans {
            self.release(req, false);
        }
        n
    }

    /// Invariant checker used by property tests. Covers the classic block
    /// accounting plus the victim index: list structure, per-bucket
    /// (LAT, id) ordering, bucket/priority agreement, and punished-counter
    /// consistency with the live future-RC state.
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut refs = vec![0u32; self.capacity];
        for v in self.owned.values() {
            for &b in v {
                refs[b as usize] += 1;
            }
        }
        for (i, meta) in self.blocks.iter().enumerate() {
            if meta.ref_count != refs[i] {
                return Err(format!(
                    "block {i}: ref_count {} != owners {}",
                    meta.ref_count, refs[i]
                ));
            }
            if meta.ref_count > 0 && meta.table_key.is_some() {
                return Err(format!("block {i}: pinned but in free table"));
            }
        }
        let in_table = self.victims.len;
        let in_free = self.free_list.len();
        // Every block is free, in the table, or pinned (shared pins may
        // make pinned-block count < total owned entries).
        let pinned = self.blocks.iter().filter(|m| m.ref_count > 0).count();
        if in_table + in_free + pinned != self.capacity {
            return Err(format!(
                "partition broken: table {in_table} + free {in_free} + pinned {pinned} != {}",
                self.capacity
            ));
        }
        for (&k, &b) in &self.cached {
            if self.blocks[b as usize].key != Some(k) {
                return Err(format!("cached index stale for key {k:x}"));
            }
        }
        if self.cached_sorted.len() != self.cached.len()
            || self.cached.keys().any(|k| !self.cached_sorted.contains(k))
        {
            return Err("sorted key mirror diverged from the cached index".to_string());
        }
        // Zombie-holder index: every entry bears its key and is not the
        // current mapping; every keyed block is current or listed (else an
        // RC edge could miss it and stale a punished flag).
        for (&k, holders) in &self.stale_holders {
            if holders.is_empty() {
                return Err(format!("stale holders for key {k:x}: empty entry"));
            }
            for &h in holders {
                if self.blocks[h as usize].key != Some(k) {
                    return Err(format!("stale holder {h} no longer bears key {k:x}"));
                }
                if self.cached.get(&k) == Some(&h) {
                    return Err(format!("stale holder {h} is the current holder of {k:x}"));
                }
            }
        }
        for (i, meta) in self.blocks.iter().enumerate() {
            let Some(k) = meta.key else { continue };
            let current = self.cached.get(&k) == Some(&(i as BlockId));
            let listed = self
                .stale_holders
                .get(&k)
                .is_some_and(|hs| hs.contains(&(i as BlockId)));
            if !current && !listed {
                return Err(format!("block {i} bears key {k:x} but is untracked"));
            }
            if current && listed {
                return Err(format!("block {i} is both current and stale for {k:x}"));
            }
        }
        // Victim-index structure.
        let keyed = self.blocks.iter().filter(|m| m.table_key.is_some()).count();
        if keyed != self.victims.len {
            return Err(format!(
                "victim index len {} != blocks with table keys {keyed}",
                self.victims.len
            ));
        }
        let mut visited = 0usize;
        let mut bucket_lens = 0usize;
        for (bi, bk) in self.victims.buckets.iter().enumerate() {
            if (bk.len > 0) != self.victims.occupied.contains(&(bi as u32)) {
                return Err(format!(
                    "bucket {bi}: occupancy set out of sync (len {})",
                    bk.len
                ));
            }
            bucket_lens += bk.len;
            let mut cur = bk.head;
            let mut prev = NIL;
            let mut last: Option<(u64, u64, BlockId)> = None;
            let mut punished = 0usize;
            let mut count = 0usize;
            while cur != NIL {
                let node = &self.victims.nodes[cur as usize];
                if node.prev != prev {
                    return Err(format!("bucket {bi}: broken prev link at block {cur}"));
                }
                if node.bucket as usize != bi {
                    return Err(format!("block {cur}: bucket tag {} != {bi}", node.bucket));
                }
                let Some((pb, lb)) = self.blocks[cur as usize].table_key else {
                    return Err(format!("block {cur}: linked without a table key"));
                };
                if bucket_of_bits(pb) != bi {
                    return Err(format!(
                        "block {cur}: priority {} maps to bucket {}, linked in {bi}",
                        f64::from_bits(pb),
                        bucket_of_bits(pb)
                    ));
                }
                if node.lat != lb || node.prio != pb {
                    return Err(format!("block {cur}: node sort key != table key"));
                }
                if let Some(l) = last {
                    if l >= (node.prio, node.lat, cur) {
                        return Err(format!(
                            "bucket {bi}: (prio, LAT, id) order broken at {cur}"
                        ));
                    }
                }
                let want_punished = self.block_rc(cur) > 0;
                if node.punished != want_punished {
                    return Err(format!(
                        "block {cur}: punished flag {} != live RC state {}",
                        node.punished, want_punished
                    ));
                }
                punished += node.punished as usize;
                last = Some((node.prio, node.lat, cur));
                prev = cur;
                cur = node.next;
                count += 1;
                if count > self.capacity {
                    return Err(format!("bucket {bi}: list cycle"));
                }
            }
            if prev != bk.tail {
                return Err(format!("bucket {bi}: tail {} != last node {prev}", bk.tail));
            }
            if count != bk.len {
                return Err(format!("bucket {bi}: len {} != walked {count}", bk.len));
            }
            if punished != bk.punished {
                return Err(format!(
                    "bucket {bi}: punished counter {} != walked {punished}",
                    bk.punished
                ));
            }
            visited += count;
        }
        if visited != self.victims.len || bucket_lens != self.victims.len {
            return Err(format!(
                "victim index len {} != visited {visited} / bucket sum {bucket_lens}",
                self.victims.len
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BS: usize = 16;

    fn keys(owner: RequestId, n: usize) -> Vec<u128> {
        // distinct unshared keys
        (0..n).map(|i| ((owner as u128) << 64) | i as u128).collect()
    }

    fn shared_keys(group: u128, n: usize) -> Vec<u128> {
        (0..n).map(|i| (group << 96) | i as u128).collect()
    }

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        let ks = keys(1, 4);
        let ff = m.allocate(1, TaskClass::Offline, &ks, 4, 0.0).unwrap();
        assert_eq!(ff, 0);
        assert_eq!(m.held_blocks(1), 4);
        assert_eq!(m.occupied_blocks(), 4);
        m.check_invariants().unwrap();
        m.release(1, true);
        assert_eq!(m.occupied_blocks(), 0);
        assert_eq!(m.availability().evictable, 4);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prefix_hit_fast_forwards() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        let shared = shared_keys(7, 3);
        m.register_future(&shared); // sibling interest keeps blocks alive
        m.allocate(1, TaskClass::Offline, &shared, 3, 0.0).unwrap();
        m.release(1, true);
        // Second request with same prefix + 2 private blocks.
        let mut ks2 = shared.clone();
        ks2.extend(keys(2, 2));
        let ff = m.allocate(2, TaskClass::Offline, &ks2, 5, 1.0).unwrap();
        assert_eq!(ff, 3 * BS, "3 shared blocks fast-forwarded");
        assert!(m.stats.hit_ratio() > 0.0);
        assert_eq!(m.stats.saved_tokens, (3 * BS) as u64);
        m.check_invariants().unwrap();
    }

    #[test]
    fn eviction_order_respects_task_priority() {
        let mut m = KvManager::new(4, BS, EvictionPolicy::TaskAware);
        // Offline block with future interest (rc=1).
        let off = keys(1, 1);
        m.register_future(&off);
        m.allocate(1, TaskClass::Offline, &off, 1, 0.0).unwrap();
        m.release(1, false);
        // Finished online block (later LAT — LRU would evict offline first anyway,
        // so make online *older* to prove priority dominates LAT).
        let on = keys(2, 1);
        m.allocate(2, TaskClass::Online, &on, 1, 0.5).unwrap();
        m.release(2, true);
        // Finished offline rc=0 (newest).
        let dead = keys(3, 1);
        m.allocate(3, TaskClass::Offline, &dead, 1, 5.0).unwrap();
        m.release(3, true);

        // Demand 3 fresh blocks: eviction order must be dead (p0),
        // online-finished (p0.5), offline-rc1 (p1).
        m.allocate(4, TaskClass::Online, &keys(4, 4), 4, 6.0).unwrap();
        assert_eq!(m.stats.evictions, 3);
        assert_eq!(m.stats.useful_evictions, 1, "only the rc=1 block was useful");
        assert_eq!(m.stats.punished_tokens, BS as u64);
        m.check_invariants().unwrap();
    }

    #[test]
    fn lru_ignores_priority() {
        let mut m = KvManager::new(2, BS, EvictionPolicy::Lru);
        let off = keys(1, 1);
        m.register_future(&off); // rc=1 — would be protected under TaskAware
        m.allocate(1, TaskClass::Offline, &off, 1, 0.0).unwrap();
        m.release(1, false);
        let on = keys(2, 1);
        m.allocate(2, TaskClass::Online, &on, 1, 1.0).unwrap();
        m.release(2, true);
        // One fresh block needed: LRU evicts oldest = the useful offline block.
        m.allocate(3, TaskClass::Online, &keys(3, 1), 1, 2.0).unwrap();
        assert_eq!(m.stats.useful_evictions, 1);
    }

    #[test]
    fn task_aware_protects_useful_block() {
        let mut m = KvManager::new(2, BS, EvictionPolicy::TaskAware);
        let off = keys(1, 1);
        m.register_future(&off);
        m.allocate(1, TaskClass::Offline, &off, 1, 0.0).unwrap();
        m.release(1, false);
        let on = keys(2, 1);
        m.allocate(2, TaskClass::Online, &on, 1, 1.0).unwrap();
        m.release(2, true);
        m.allocate(3, TaskClass::Online, &keys(3, 1), 1, 2.0).unwrap();
        assert_eq!(
            m.stats.useful_evictions, 0,
            "task-aware policy must evict the finished online block instead"
        );
        // The offline block is still hittable.
        assert_eq!(m.peek_prefix(&off), 1);
    }

    #[test]
    fn reserve_blocks_offline_not_online() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        m.set_reserve_tokens(4 * BS);
        assert_eq!(m.availability().for_offline(), 6);
        assert_eq!(m.availability().for_online(), 10);
        // Offline may take 6, not 7.
        assert!(m.allocate(1, TaskClass::Offline, &keys(1, 7), 7, 0.0).is_none());
        assert!(m.allocate(1, TaskClass::Offline, &keys(1, 6), 6, 0.0).is_some());
        // Online can use the reserve.
        assert!(m.allocate(2, TaskClass::Online, &keys(2, 4), 4, 0.0).is_some());
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_pin_survives_single_release() {
        let mut m = KvManager::new(10, BS, EvictionPolicy::TaskAware);
        let shared = shared_keys(9, 2);
        m.register_future(&shared);
        m.register_future(&shared);
        m.allocate(1, TaskClass::Offline, &shared, 2, 0.0).unwrap();
        let ff = m.allocate(2, TaskClass::Offline, &shared, 2, 0.1).unwrap();
        assert_eq!(ff, 2 * BS);
        m.release(1, true);
        m.unregister_future(&shared);
        // Request 2 still holds the blocks.
        assert_eq!(m.held_blocks(2), 2);
        assert_eq!(m.occupied_blocks(), 2);
        m.check_invariants().unwrap();
        m.release(2, true);
        m.unregister_future(&shared);
        assert_eq!(m.occupied_blocks(), 0);
        m.check_invariants().unwrap();
    }

    #[test]
    fn grow_appends_unkeyed() {
        let mut m = KvManager::new(5, BS, EvictionPolicy::TaskAware);
        m.allocate(1, TaskClass::Online, &keys(1, 2), 2, 0.0).unwrap();
        assert!(m.grow(1, TaskClass::Online, 2, 1.0));
        assert_eq!(m.held_blocks(1), 4);
        m.release(1, true);
        // Unkeyed decode blocks return to the free list, keyed ones to cache.
        let a = m.availability();
        assert_eq!(a.evictable, 2);
        assert_eq!(a.free, 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn eviction_preview_counts_useful() {
        let mut m = KvManager::new(4, BS, EvictionPolicy::TaskAware);
        let off = keys(1, 2);
        m.register_future(&off);
        m.allocate(1, TaskClass::Offline, &off, 2, 0.0).unwrap();
        m.release(1, false);
        let dead = keys(2, 2);
        m.allocate(2, TaskClass::Offline, &dead, 2, 1.0).unwrap();
        m.release(2, true);
        // Victims in order: 2 dead blocks (p0), then 2 useful (rc=1).
        assert_eq!(m.eviction_preview(2), 0);
        assert_eq!(m.eviction_preview(3), BS as u64);
        assert_eq!(m.eviction_preview(4), 2 * BS as u64);
    }

    #[test]
    fn eviction_preview_partial_mixed_bucket() {
        // LRU keeps everything in bucket 0, so a punished/unpunished mix
        // can be cut mid-bucket — the walkless counter shortcuts must not
        // misreport it.
        let mut m = KvManager::new(6, BS, EvictionPolicy::Lru);
        let wanted = keys(1, 2);
        m.register_future(&wanted); // punished, oldest
        m.allocate(1, TaskClass::Offline, &wanted, 2, 0.0).unwrap();
        m.release(1, true);
        let dead = keys(2, 2);
        m.allocate(2, TaskClass::Offline, &dead, 2, 1.0).unwrap();
        m.release(2, true);
        // Victim order (pure LAT): wanted[0], wanted[1], dead[0], dead[1].
        assert_eq!(m.eviction_preview(1), BS as u64);
        assert_eq!(m.eviction_preview(2), 2 * BS as u64);
        assert_eq!(m.eviction_preview(3), 2 * BS as u64);
        assert_eq!(m.eviction_preview(4), 2 * BS as u64);
        m.check_invariants().unwrap();
    }

    #[test]
    fn flush_cache_empties_table() {
        let mut m = KvManager::new(8, BS, EvictionPolicy::TaskAware);
        m.allocate(1, TaskClass::Offline, &keys(1, 3), 3, 0.0).unwrap();
        m.release(1, true);
        m.flush_cache();
        let a = m.availability();
        assert_eq!(a.evictable, 0);
        assert_eq!(a.free, 8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn key_churn_tracks_net_delta() {
        let mut m = KvManager::new(4, BS, EvictionPolicy::TaskAware);
        m.enable_key_churn();
        assert_eq!(m.take_key_churn(), Some((vec![], vec![])));
        let a = keys(1, 2);
        m.allocate(1, TaskClass::Offline, &a, 2, 0.0).unwrap();
        m.release(1, true);
        let (added, removed) = m.take_key_churn().unwrap();
        assert_eq!(added.len(), 2);
        assert!(removed.is_empty());
        assert_eq!(added, m.cached_key_sample(usize::MAX));
        // Fill the cache so fresh allocations evict the old keys.
        let b = keys(2, 4);
        m.allocate(2, TaskClass::Offline, &b, 4, 1.0).unwrap();
        let (added, removed) = m.take_key_churn().unwrap();
        assert_eq!(added.len(), 4, "new keys reported");
        assert_eq!(removed.len(), 2, "evicted keys reported");
        let mut expect = a.clone();
        expect.sort_unstable();
        assert_eq!(removed, expect);
        // Cached-then-evicted within one window cancels to nothing.
        m.release(2, true);
        m.flush_cache();
        let c = keys(3, 1);
        m.allocate(3, TaskClass::Offline, &c, 1, 2.0).unwrap();
        m.release(3, true);
        m.flush_cache();
        let (added, removed) = m.take_key_churn().unwrap();
        assert!(added.is_empty(), "transient key must cancel: {added:?}");
        // b's keys were resident at the last drain and are now gone.
        let mut expect = b.clone();
        expect.sort_unstable();
        assert_eq!(removed, expect);
        m.check_invariants().unwrap();
    }

    #[test]
    fn sample_served_sorted_from_mirror() {
        let mut m = KvManager::new(8, BS, EvictionPolicy::TaskAware);
        let ks = keys(5, 6);
        m.allocate(5, TaskClass::Offline, &ks, 6, 0.0).unwrap();
        let mut expect = ks.clone();
        expect.sort_unstable();
        assert_eq!(m.cached_key_sample(usize::MAX), expect);
        assert_eq!(m.cached_key_sample(3), &expect[..3], "cap takes smallest keys");
        assert_eq!(m.cached_key_count(), 6);
        // The pre-PR reference path returns the same key set (the bench
        // baseline depends on the two being interchangeable).
        let mut rebuilt = m.cached_key_sample_rebuild(usize::MAX);
        rebuilt.sort_unstable();
        assert_eq!(rebuilt, m.cached_key_sample(usize::MAX));
        assert_eq!(m.cached_key_sample_rebuild(3), &expect[..3]);
        m.check_invariants().unwrap();
    }

    #[test]
    fn truncated_sample_is_deterministic_under_churn() {
        // The digest-cap footgun (`ClusterConfig::summary_cap` below the
        // cache size): a truncating sample must stay deterministic — the
        // smallest `cap` keys, regardless of insertion/eviction history.
        let cap = 4usize;
        let run = |order: &[u64]| {
            let mut m = KvManager::new(16, BS, EvictionPolicy::TaskAware);
            for (i, &owner) in order.iter().enumerate() {
                let ks = keys(owner, 3);
                m.allocate(owner, TaskClass::Offline, &ks, 3, i as f64).unwrap();
                m.release(owner, true);
            }
            // Evict one owner's keys and re-add them, churning history.
            m.allocate(99, TaskClass::Offline, &keys(99, 3), 3, 10.0).unwrap();
            m.release(99, true);
            m.cached_key_sample(cap)
        };
        let a = run(&[1, 2, 3]);
        let b = run(&[3, 1, 2]);
        assert_eq!(a, b, "cap sample must not depend on history");
        assert_eq!(a.len(), cap);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(a, sorted, "cap sample is the smallest keys, ascending");
    }

    #[test]
    fn rc_change_requeues_priority() {
        let mut m = KvManager::new(2, BS, EvictionPolicy::TaskAware);
        let a = keys(1, 1);
        let b = keys(2, 1);
        m.register_future(&a);
        m.allocate(1, TaskClass::Offline, &a, 1, 0.0).unwrap();
        m.release(1, false);
        m.allocate(2, TaskClass::Offline, &b, 1, 1.0).unwrap();
        m.release(2, false);
        m.register_future(&b); // b now rc=1, a rc=1 — tie broken by LAT (a older)
        m.unregister_future(&a); // a drops to rc=0 => evicted first despite age
        m.allocate(3, TaskClass::Online, &keys(3, 1), 1, 2.0).unwrap();
        assert_eq!(m.peek_prefix(&b), 1, "b must survive");
        assert_eq!(m.peek_prefix(&a), 0, "a (rc=0) must be the victim");
    }

    #[test]
    fn availability_is_counter_reads_only() {
        // O(1) availability: the call count is tracked, and repeated calls
        // on a warm cache must agree with first-principles accounting
        // without any mutation.
        let mut m = KvManager::new(64, BS, EvictionPolicy::TaskAware);
        let wanted = keys(1, 8);
        m.register_future(&wanted);
        m.allocate(1, TaskClass::Offline, &wanted, 8, 0.0).unwrap();
        m.release(1, false);
        m.allocate(2, TaskClass::Offline, &keys(2, 4), 4, 1.0).unwrap();
        m.release(2, true);
        let before = m.availability_calls();
        let a = m.availability();
        assert_eq!(m.availability_calls(), before + 1);
        assert_eq!(a.free, 64 - 12);
        assert_eq!(a.evictable, 12);
        assert_eq!(a.evictable_useless, 4, "only the rc=0 blocks are free to evict");
        assert_eq!(m.availability(), a, "read-only: repeated calls agree");
    }

    #[test]
    fn op_log_records_and_replays() {
        let mut m = KvManager::new(8, BS, EvictionPolicy::TaskAware);
        m.enable_op_log();
        let ks = keys(1, 2);
        m.register_future(&ks);
        m.allocate(1, TaskClass::Offline, &ks, 3, 0.5).unwrap();
        m.touch(1, 0.7);
        m.release(1, true);
        m.unregister_future(&ks);
        m.flush_cache();
        let log = m.take_op_log();
        assert_eq!(log.len(), 6);
        assert!(matches!(log[0], KvOp::RegisterFuture { .. }));
        assert!(matches!(log[5], KvOp::FlushCache));
        // Replaying into a fresh oracle reproduces the stats.
        let mut oracle = super::super::OracleKvManager::new(8, BS, EvictionPolicy::TaskAware);
        for op in &log {
            oracle.apply_op(op);
        }
        assert_eq!(oracle.stats.evictions, m.stats.evictions);
        assert_eq!(oracle.stats.lookup_blocks, m.stats.lookup_blocks);
        assert_eq!(oracle.availability(), m.availability());
    }

    #[test]
    fn overflow_bucket_keeps_priority_order() {
        // RC values past the clamp share one overflow bucket; inside it
        // the insert walk orders by the full (prio, LAT, id) key, so the
        // global eviction order stays exact while the bucket vector stays
        // bounded (no O(max-RC) dense growth on hyper-shared prefixes).
        let mut m = KvManager::new(3, BS, EvictionPolicy::TaskAware);
        let a = keys(1, 1);
        let b = keys(2, 1);
        for _ in 0..200 {
            m.register_future(&b);
        }
        for _ in 0..150 {
            m.register_future(&a);
        }
        m.allocate(1, TaskClass::Offline, &a, 1, 0.0).unwrap();
        m.release(1, false);
        m.allocate(2, TaskClass::Offline, &b, 1, 1.0).unwrap();
        m.release(2, false);
        m.check_invariants().unwrap();
        assert_eq!(m.eviction_preview(2), 2 * BS as u64, "both are wanted");
        // rc(a) = 150 < rc(b) = 200: a evicts first despite b's newer LAT
        // and identical (overflow) bucket.
        assert_eq!(m.pop_victim(), Some(0));
        assert_eq!(m.peek_prefix(&b), 1, "higher-RC block survives");
        m.check_invariants().unwrap();
    }

    #[test]
    fn superseded_zombie_blocks_track_live_rc() {
        // Partial-prefix eviction leaves the chain [k1, k2] with only k2
        // resident; re-allocating the chain misses at k1 and creates fresh
        // blocks for *both* keys, superseding k2's old block — a zombie
        // that stays in the victim index bearing k2. Later RC edges must
        // still reach its punished flag (the oracle reads live RC per
        // victim, so preview counts diverge otherwise).
        let mut m = KvManager::new(8, BS, EvictionPolicy::TaskAware);
        let ks = shared_keys(3, 2);
        m.allocate(1, TaskClass::Offline, &ks, 2, 0.0).unwrap();
        m.release(1, true);
        assert_eq!(m.pop_victim(), Some(0), "k1's block is the oldest victim");
        m.allocate(2, TaskClass::Offline, &ks, 2, 1.0).unwrap();
        m.release(2, true);
        m.check_invariants().unwrap();
        assert_eq!(m.availability().evictable, 3, "zombie stays evictable");
        // Future interest lands on both keys: the zombie (bearing k2) must
        // count as punished alongside the two fresh blocks.
        m.register_future(&ks);
        m.check_invariants().unwrap();
        assert_eq!(m.eviction_preview(3), 3 * BS as u64);
        m.unregister_future(&ks);
        m.check_invariants().unwrap();
        assert_eq!(m.eviction_preview(3), 0);
        // Evicting the zombie un-caches the current holder (pre-PR
        // semantics, kept verbatim): k2 stops being a visible prefix hit.
        m.register_future(&ks);
        assert_eq!(m.pop_victim(), Some(1), "zombie (frozen LAT) evicts first");
        assert_eq!(m.peek_prefix(&ks), 1, "k2's mapping was dropped with the zombie");
        m.check_invariants().unwrap();
        // ...and the displaced fresh block is now the zombie: RC edges
        // must keep reaching it through the cascade.
        m.unregister_future(&ks);
        m.check_invariants().unwrap();
        assert_eq!(m.eviction_preview(2), 0);
    }

    #[test]
    fn punished_flag_follows_rc_without_priority_move() {
        // Online-finished blocks stay in the 0.5 bucket whatever their RC;
        // the punished accounting must still track the RC edges (this is
        // the case eviction_preview's counters depend on).
        let mut m = KvManager::new(4, BS, EvictionPolicy::TaskAware);
        let on = keys(1, 1);
        m.allocate(1, TaskClass::Online, &on, 1, 0.0).unwrap();
        m.release(1, true); // bucket 0.5, rc = 0
        assert_eq!(m.eviction_preview(1), 0);
        m.register_future(&on); // rc = 1, still bucket 0.5
        assert_eq!(m.eviction_preview(1), BS as u64);
        m.check_invariants().unwrap();
        m.unregister_future(&on);
        assert_eq!(m.eviction_preview(1), 0);
        m.check_invariants().unwrap();
    }
}

//! Task-aware KV cache manager (paper §4.2).
//!
//! Block-granular KV accounting with automatic prefix caching (APC): blocks
//! are identified by content keys (chain hashes, see
//! [`crate::core::PromptSpec::content_key`]); a prefix index maps keys to
//! resident blocks so a new request reuses any cached prefix.
//!
//! Eviction is the paper's contribution: the victim order is
//! (priority, last-access-time) where priority encodes the *source task
//! class* and the *future reference count* (RC):
//!
//!   running online blocks     — never evictable (priority = ∞)
//!   offline blocks, RC > 0    — priority = RC
//!   finished online blocks    — priority = 0.5
//!   finished offline, RC = 0  — priority = 0 (evicted first)
//!
//! A **threshold** reserves headroom for bursty online arrivals: offline
//! allocations must leave `reserve_tokens` allocatable; online allocations
//! may dip into the reserve (that is what it is for).
//!
//! [`KvManager`] keeps that order in a bucketed victim index with O(1)
//! steady-state maintenance and O(1) `availability()`;
//! [`OracleKvManager`] is the pre-PR implementation kept verbatim as the
//! bit-exactness oracle and microbench baseline.

pub mod manager;
pub mod oracle;

pub use manager::{Availability, CacheStats, EvictionPolicy, KvManager, KvOp};
pub use oracle::OracleKvManager;

/// Physical block handle (index into the manager's metadata table).
pub type BlockId = u32;

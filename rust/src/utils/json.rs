//! Minimal JSON: parser + writer.
//!
//! serde is not reachable offline, and the repo needs JSON in three places:
//! reading `artifacts/manifest.json`, reading/writing config files, and
//! dumping metrics/figure data. This implements the full JSON grammar
//! (RFC 8259) minus exotic number edge cases, with friendly accessors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.at("a.b.c")` — dotted path access.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    /// Pretty string with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(n) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(n + 1));
                        x.write(out, Some(n + 1));
                    } else {
                        x.write(out, None);
                    }
                }
                if let (Some(n), false) = (indent, v.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(n));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(n) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(n + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        x.write(out, Some(n + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        x.write(out, None);
                    }
                }
                if let (Some(n), false) = (indent, m.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(n));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our data; map
                            // lone surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = &self.b[self.pos..];
                    let step = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..step.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += step;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // lint: allow-unwrap(the scanned span holds only ASCII sign/digit/dot/exp bytes)
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at("c.d").unwrap().as_f64().unwrap(), -2500.0);
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"params": [{"name": "embed", "shape": [512, 128],
                      "byte_offset": 0}], "weights_bytes": 3740160}"#;
        let v = Json::parse(src).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str().unwrap(), "embed");
        assert_eq!(
            p.get("shape").unwrap().as_arr().unwrap()[1].as_usize().unwrap(),
            128
        );
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "{\"a\" 1}", "tru", "1.2.3", "\"abc", "{} extra"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn builder() {
        let v = Json::obj().set("x", 3usize).set("y", vec![1.0, 2.0]);
        assert_eq!(v.at("x").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.at("y").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integers_print_clean() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}

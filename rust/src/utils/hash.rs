//! Dependency-free deterministic fast hashing (FxHash-style).
//!
//! The default `std` hasher (SipHash-1-3) is keyed per-process and pays a
//! full rounds schedule per word — measurable on the KV manager's u128
//! content-key maps, which sit on the scheduler's per-trial critical path.
//! [`FxHasher`] is the classic multiply-rotate word hasher: one rotate,
//! one xor, one multiply per 8 bytes, **no random seed**, so
//!
//!   * every u128 content-key lookup costs two multiplies instead of a
//!     SipHash permutation, and
//!   * hash-map iteration order is identical across processes and runs —
//!     a property the repo's determinism tests lean on (nothing may
//!     *depend* on map order, but reproducible order makes divergence
//!     bisectable).
//!
//! Not DoS-resistant by design: every key hashed here (content chain
//! hashes, request ids, block ids) is produced inside the system, never
//! attacker-chosen. Do not use it for untrusted external input.

use std::hash::{BuildHasherDefault, Hasher};

/// Odd multiplier (high-entropy constant, same family as FxHash's seed);
/// the exact value only matters in that it is odd and well-mixed.
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate word hasher. `Default` starts at zero, so equal inputs
/// hash equally across instances, threads, and processes.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn mix(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            // lint: allow-unwrap(chunks_exact(8) yields exactly 8 bytes)
            self.mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            // Length-tagged tail so "ab" and "ab\0" cannot collide by
            // construction.
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            buf[7] = buf[7].wrapping_add(rem.len() as u8);
            self.mix(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.mix(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.mix(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.mix(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.mix(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.mix(i as u64);
    }

    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.mix(i as u64);
        self.mix((i >> 64) as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Drop-in `HashMap` with the deterministic fast hasher. Construct with
/// `FxHashMap::default()` (the `new()` constructor is only defined for the
/// `RandomState` hasher).
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// Drop-in `HashSet` with the deterministic fast hasher.
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_across_instances() {
        let k: u128 = 0xDEAD_BEEF_0000_0000_0000_0000_1234_5678;
        assert_eq!(hash_of(&k), hash_of(&k));
        assert_eq!(hash_of(&42u64), hash_of(&42u64));
        assert_eq!(hash_of(&"content"), hash_of(&"content"));
    }

    #[test]
    fn low_entropy_u128_keys_spread() {
        // Content keys generated in tests look like (tag << 40) | i — the
        // hasher must not collapse them to a few buckets.
        let hashes: FxHashSet<u64> =
            (0..1024u128).map(|i| hash_of(&((7u128 << 40) | i))).collect();
        assert_eq!(hashes.len(), 1024, "sequential keys must not collide");
        // Low 7 bits (the bits a small map masks on) must vary too.
        let low: FxHashSet<u64> = (0..128u128)
            .map(|i| hash_of(&((7u128 << 40) | i)) & 0x7f)
            .collect();
        assert!(low.len() > 64, "low bits too clustered: {}", low.len());
    }

    #[test]
    fn tail_bytes_are_length_tagged() {
        assert_ne!(hash_of(&[1u8, 2][..]), hash_of(&[1u8, 2, 0][..]));
    }

    #[test]
    fn map_and_set_work_with_u128_keys() {
        let mut m: FxHashMap<u128, u32> = FxHashMap::default();
        for i in 0..100u128 {
            m.insert(i << 64 | i, i as u32);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&(3u128 << 64 | 3)), Some(&3));
        // Iteration order is reproducible run-to-run (no random seed):
        // collect twice and compare.
        let a: Vec<u128> = m.keys().copied().collect();
        let b: Vec<u128> = m.keys().copied().collect();
        assert_eq!(a, b);
    }
}

//! Statistics substrate: summaries, percentiles, sliding windows, time
//! series, and the small dense least-squares solver the execution-time
//! estimator's coefficient fitting (paper §5.2) relies on.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance (0 for len < 2).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation on a sorted copy. p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile of an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Latency-style summary of a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(f64::total_cmp);
        Summary {
            count: v.len(),
            mean: mean(&v),
            std: std_dev(&v),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: v[v.len() - 1],
        }
    }

    /// Fraction of samples <= threshold (SLO attainment).
    pub fn attainment(xs: &[f64], threshold: f64) -> f64 {
        if xs.is_empty() {
            return 1.0;
        }
        xs.iter().filter(|&&x| x <= threshold).count() as f64 / xs.len() as f64
    }
}

/// Fixed-capacity sliding window over (time, value) observations — the
/// memory predictor's trailing-hour history (paper §5.3).
#[derive(Clone, Debug)]
pub struct SlidingWindow {
    horizon: f64,
    items: std::collections::VecDeque<(f64, f64)>,
}

impl SlidingWindow {
    pub fn new(horizon: f64) -> Self {
        SlidingWindow {
            horizon,
            items: Default::default(),
        }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.items.push_back((t, v));
        let cutoff = t - self.horizon;
        while matches!(self.items.front(), Some(&(ft, _)) if ft < cutoff) {
            self.items.pop_front();
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.items.is_empty() {
            return 0.0;
        }
        self.items.iter().map(|&(_, v)| v).sum::<f64>() / self.items.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.items.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self
            .items
            .iter()
            .map(|&(_, v)| (v - m) * (v - m))
            .sum::<f64>()
            / self.items.len() as f64)
            .sqrt()
    }

    /// μ + k·σ — the paper's burst headroom rule (k = 2 covers ~95%).
    pub fn mean_plus_k_sigma(&self, k: f64) -> f64 {
        self.mean() + k * self.std()
    }
}

/// A named time series, appended during a run and binned for figures.
#[derive(Clone, Debug, Default)]
pub struct TimeSeries {
    pub points: Vec<(f64, f64)>,
}

impl TimeSeries {
    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    /// Average value per fixed-width time bin over [t0, t1).
    pub fn binned(&self, t0: f64, t1: f64, bins: usize) -> Vec<f64> {
        let mut sums = vec![0.0; bins];
        let mut counts = vec![0usize; bins];
        let w = (t1 - t0) / bins as f64;
        for &(t, v) in &self.points {
            if t < t0 || t >= t1 {
                continue;
            }
            let i = (((t - t0) / w) as usize).min(bins - 1);
            sums[i] += v;
            counts[i] += 1;
        }
        sums.iter()
            .zip(&counts)
            .map(|(&s, &c)| if c == 0 { 0.0 } else { s / c as f64 })
            .collect()
    }

    /// Count of points per bin (for arrival-rate plots).
    pub fn rate_binned(&self, t0: f64, t1: f64, bins: usize) -> Vec<f64> {
        let mut counts = vec![0.0; bins];
        let w = (t1 - t0) / bins as f64;
        for &(t, _) in &self.points {
            if t < t0 || t >= t1 {
                continue;
            }
            let i = (((t - t0) / w) as usize).min(bins - 1);
            counts[i] += 1.0;
        }
        counts
    }
}

/// Ordinary least squares via normal equations (XᵀX)β = Xᵀy with Gaussian
/// elimination + partial pivoting. Feature count is tiny (≤ 4: the
/// estimator fits α, β | γ, δ | λ), so this is exact enough and dependency
/// free.
pub fn least_squares(rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let n = rows.len();
    if n == 0 || n != y.len() {
        return None;
    }
    let k = rows[0].len();
    if k == 0 || rows.iter().any(|r| r.len() != k) {
        return None;
    }
    // Build normal equations A = XᵀX (k×k), b = Xᵀy.
    let mut a = vec![vec![0.0; k]; k];
    let mut b = vec![0.0; k];
    for (row, &yy) in rows.iter().zip(y) {
        for i in 0..k {
            b[i] += row[i] * yy;
            for j in 0..k {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    // Tikhonov jitter for singular designs.
    for i in 0..k {
        a[i][i] += 1e-12;
    }
    solve_dense(&mut a, &mut b)
}

/// In-place Gaussian elimination with partial pivoting; returns x solving Ax=b.
pub fn solve_dense(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-14 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        for r in col + 1..n {
            let f = a[r][col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for c in col + 1..n {
            s -= a[col][c] * x[c];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

// ---- streaming log-bucketed histogram (PR 6 observability) ---------------

/// Smallest representable value; anything at or below lands in bucket 0.
const HIST_MIN: f64 = 1e-6;
/// Buckets per factor of two. 8 gives a per-bucket ratio of 2^(1/8)
/// (~9.05%), so a geometric-midpoint estimate is within ~4.4% of any value
/// in its bucket.
const HIST_PER_OCTAVE: f64 = 8.0;
/// Bucket count: 40 octaves ([1e-6, ~1e6)) x 8 buckets each. The last
/// bucket absorbs overflow.
const HIST_BUCKETS: usize = 320;

/// Streaming log-bucketed histogram over a fixed geometric bucket layout.
///
/// Built for fleet telemetry: `merge_from` adds bucket counts elementwise,
/// so merging per-replica histograms is associative and commutative (counts
/// are integers; `sum` is the only float and is exact for integer-valued
/// samples), and the merged percentiles are the true pooled percentiles to
/// within the bucket quantization ([`LogHistogram::REL_ERROR`]). The bucket
/// vector is allocated lazily on the first `record`, so a defaulted
/// histogram costs nothing and a recording one never allocates again —
/// which is what lets the engine feed one every iteration without breaking
/// the zero-alloc steady-step invariant.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LogHistogram {
    /// Bucket counts; empty until the first sample.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    /// Worst-case relative error of a percentile estimate vs the exact
    /// value in the same bucket: half a bucket in log space, 2^(1/16) - 1.
    pub const REL_ERROR: f64 = 0.0443;

    /// Fixed bucket count of the geometric layout (shared by every
    /// histogram, so cumulative-count snapshots are directly comparable).
    pub const BUCKETS: usize = HIST_BUCKETS;

    fn bucket(x: f64) -> usize {
        if x <= HIST_MIN {
            return 0;
        }
        let i = ((x / HIST_MIN).log2() * HIST_PER_OCTAVE) as usize;
        i.min(HIST_BUCKETS - 1)
    }

    /// Bucket index a value of `x` lands in (layout is identical across
    /// all histograms). Public for windowed snapshot-delta consumers.
    pub fn bucket_index(x: f64) -> usize {
        Self::bucket(x.max(0.0))
    }

    /// Geometric midpoint of bucket `i` (the estimate it answers with).
    fn representative(i: usize) -> f64 {
        HIST_MIN * ((i as f64 + 0.5) / HIST_PER_OCTAVE).exp2()
    }

    /// Geometric midpoint of bucket `i` — the value a sample in that
    /// bucket is estimated as. Public counterpart of `representative`.
    pub fn bucket_value(i: usize) -> f64 {
        Self::representative(i.min(HIST_BUCKETS - 1))
    }

    /// Raw bucket counts. Empty until the first `record` (the vector is
    /// lazily allocated); callers accumulating snapshots must treat an
    /// empty slice as all-zeros.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Record one sample. Negative values clamp to the bottom bucket;
    /// non-finite values are ignored.
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let x = x.max(0.0);
        if self.counts.is_empty() {
            self.counts = vec![0; HIST_BUCKETS];
        }
        self.counts[Self::bucket(x)] += 1;
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.count += 1;
        self.sum += x;
    }

    /// Fold `other`'s samples into this histogram (fleet aggregation).
    pub fn merge_from(&mut self, other: &LogHistogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean (the running sum is not bucketed).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Percentile estimate (p in [0, 100]): the geometric midpoint of the
    /// bucket holding the ceil(p/100 * n)-th smallest sample, clamped to
    /// the exact observed [min, max]. Within [`Self::REL_ERROR`] of the
    /// exact same-bucket value.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Exponentially weighted moving average.
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let s = Summary::of(&xs);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn attainment() {
        let xs = [0.1, 0.2, 0.3, 0.4];
        assert!((Summary::attainment(&xs, 0.25) - 0.5).abs() < 1e-12);
        assert_eq!(Summary::attainment(&[], 1.0), 1.0);
    }

    #[test]
    fn sliding_window_evicts() {
        let mut w = SlidingWindow::new(10.0);
        for t in 0..20 {
            w.push(t as f64, t as f64);
        }
        assert!(w.len() <= 11);
        assert!(w.mean() > 12.0);
    }

    #[test]
    fn mu_plus_2sigma() {
        let mut w = SlidingWindow::new(1e9);
        for i in 0..1000 {
            w.push(i as f64, if i % 2 == 0 { 10.0 } else { 20.0 });
        }
        let v = w.mean_plus_k_sigma(2.0);
        assert!((v - 25.0).abs() < 0.1, "v={v}");
    }

    #[test]
    fn least_squares_recovers_line() {
        // y = 3x + 7
        let rows: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64, 1.0]).collect();
        let y: Vec<f64> = (0..50).map(|i| 3.0 * i as f64 + 7.0).collect();
        let beta = least_squares(&rows, &y).unwrap();
        assert!((beta[0] - 3.0).abs() < 1e-9);
        assert!((beta[1] - 7.0).abs() < 1e-9);
    }

    #[test]
    fn least_squares_quadratic() {
        // y = 2e-6 x^2 + 1e-3 x  (prefill-shaped, Eq. 6)
        let rows: Vec<Vec<f64>> = (1..100)
            .map(|i| {
                let l = (i * 50) as f64;
                vec![l * l, l]
            })
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| 2e-6 * r[0] + 1e-3 * r[1]).collect();
        let beta = least_squares(&rows, &y).unwrap();
        assert!((beta[0] - 2e-6).abs() < 1e-10);
        assert!((beta[1] - 1e-3).abs() < 1e-7);
    }

    #[test]
    fn timeseries_binning() {
        let mut ts = TimeSeries::default();
        for i in 0..100 {
            ts.push(i as f64, (i % 10) as f64);
        }
        let b = ts.binned(0.0, 100.0, 10);
        assert_eq!(b.len(), 10);
        assert!((b[0] - 4.5).abs() < 1e-12);
        let r = ts.rate_binned(0.0, 100.0, 4);
        assert_eq!(r, vec![25.0, 25.0, 25.0, 25.0]);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..30 {
            e.push(8.0);
        }
        assert!((e.get() - 8.0).abs() < 1e-6);
    }

    /// Deterministic LCG driving the histogram property tests (no rand
    /// dependency; the same stream reproduces bit-identically everywhere).
    struct Lcg(u64);

    impl Lcg {
        fn next_unit(&mut self) -> f64 {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (self.0 >> 11) as f64 / (1u64 << 53) as f64
        }

        /// Log-uniform over [10^-3, 10^1).
        fn next_span(&mut self) -> f64 {
            10f64.powf(-3.0 + 4.0 * self.next_unit())
        }
    }

    #[test]
    fn log_histogram_tracks_exact_percentiles() {
        let mut rng = Lcg(0x5eed);
        let xs: Vec<f64> = (0..1000).map(|_| rng.next_span()).collect();
        let mut h = LogHistogram::default();
        for &x in &xs {
            h.record(x);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - mean(&xs)).abs() / mean(&xs) < 1e-12);
        for p in [50.0, 90.0, 99.0] {
            let exact = percentile(&xs, p);
            let est = h.percentile(p);
            let rel = (est - exact).abs() / exact;
            // Bucket quantization (REL_ERROR) plus the order-statistic gap
            // the exact percentile interpolates across.
            assert!(rel < 0.05, "p{p}: est {est} exact {exact} rel {rel}");
        }
        // The extremes answer from the min/max sample's own bucket, so the
        // estimate sits within half a bucket (REL_ERROR) of the exact value.
        assert!((h.percentile(0.0) / h.min() - 1.0).abs() < 0.05);
        assert!((h.percentile(100.0) / h.max() - 1.0).abs() < 0.05);
    }

    #[test]
    fn log_histogram_error_bound_over_many_streams() {
        for seed in 1..30u64 {
            let mut rng = Lcg(seed);
            let xs: Vec<f64> = (0..1000).map(|_| rng.next_span()).collect();
            let mut h = LogHistogram::default();
            for &x in &xs {
                h.record(x);
            }
            for p in [50.0, 90.0, 99.0] {
                let exact = percentile(&xs, p);
                let rel = (h.percentile(p) - exact).abs() / exact;
                assert!(rel < 0.07, "seed {seed} p{p}: rel {rel}");
            }
        }
    }

    #[test]
    fn log_histogram_merge_is_associative_and_commutative() {
        // Integer-valued samples make the f64 running sums exact, so merge
        // results compare bit-identically via PartialEq.
        let mut rng = Lcg(7);
        let parts: Vec<LogHistogram> = (0..3)
            .map(|_| {
                let mut h = LogHistogram::default();
                for _ in 0..200 {
                    h.record((rng.next_unit() * 50.0).floor() + 1.0);
                }
                h
            })
            .collect();
        let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
        // (a + b) + c
        let mut ab = a.clone();
        ab.merge_from(b);
        let mut ab_c = ab.clone();
        ab_c.merge_from(c);
        // a + (b + c)
        let mut bc = b.clone();
        bc.merge_from(c);
        let mut a_bc = a.clone();
        a_bc.merge_from(&bc);
        assert_eq!(ab_c, a_bc, "merge must be associative");
        // b + a == a + b
        let mut ba = b.clone();
        ba.merge_from(a);
        assert_eq!(ab, ba, "merge must be commutative");
        // Merging equals recording the concatenated stream.
        let mut rng = Lcg(7);
        let mut all = LogHistogram::default();
        for _ in 0..600 {
            all.record((rng.next_unit() * 50.0).floor() + 1.0);
        }
        assert_eq!(ab_c, all, "merge must equal pooled recording");
    }

    #[test]
    fn log_histogram_deterministic_and_edge_cases() {
        let mut a = LogHistogram::default();
        let mut b = LogHistogram::default();
        for x in [0.0, -1.0, 1e-9, 0.25, 3.0, 1e9] {
            a.record(x);
            b.record(x);
        }
        assert_eq!(a, b, "same stream must produce identical state");
        a.record(f64::NAN);
        a.record(f64::INFINITY);
        assert_eq!(a.count(), 6, "non-finite samples are ignored");
        assert_eq!(a.min(), 0.0);
        assert_eq!(a.max(), 1e9);
        let empty = LogHistogram::default();
        assert_eq!(empty.percentile(50.0), 0.0);
        assert_eq!(empty.mean(), 0.0);
        let mut merged = LogHistogram::default();
        merged.merge_from(&empty);
        assert!(merged.is_empty());
        merged.merge_from(&b);
        assert_eq!(merged, b, "merge into empty clones the source");
    }
}

//! Statistics substrate: summaries, percentiles, sliding windows, time
//! series, and the small dense least-squares solver the execution-time
//! estimator's coefficient fitting (paper §5.2) relies on.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance (0 for len < 2).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation on a sorted copy. p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile of an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Latency-style summary of a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count: v.len(),
            mean: mean(&v),
            std: std_dev(&v),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: v[v.len() - 1],
        }
    }

    /// Fraction of samples <= threshold (SLO attainment).
    pub fn attainment(xs: &[f64], threshold: f64) -> f64 {
        if xs.is_empty() {
            return 1.0;
        }
        xs.iter().filter(|&&x| x <= threshold).count() as f64 / xs.len() as f64
    }
}

/// Fixed-capacity sliding window over (time, value) observations — the
/// memory predictor's trailing-hour history (paper §5.3).
#[derive(Clone, Debug)]
pub struct SlidingWindow {
    horizon: f64,
    items: std::collections::VecDeque<(f64, f64)>,
}

impl SlidingWindow {
    pub fn new(horizon: f64) -> Self {
        SlidingWindow {
            horizon,
            items: Default::default(),
        }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.items.push_back((t, v));
        let cutoff = t - self.horizon;
        while matches!(self.items.front(), Some(&(ft, _)) if ft < cutoff) {
            self.items.pop_front();
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.items.is_empty() {
            return 0.0;
        }
        self.items.iter().map(|&(_, v)| v).sum::<f64>() / self.items.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.items.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self
            .items
            .iter()
            .map(|&(_, v)| (v - m) * (v - m))
            .sum::<f64>()
            / self.items.len() as f64)
            .sqrt()
    }

    /// μ + k·σ — the paper's burst headroom rule (k = 2 covers ~95%).
    pub fn mean_plus_k_sigma(&self, k: f64) -> f64 {
        self.mean() + k * self.std()
    }
}

/// A named time series, appended during a run and binned for figures.
#[derive(Clone, Debug, Default)]
pub struct TimeSeries {
    pub points: Vec<(f64, f64)>,
}

impl TimeSeries {
    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    /// Average value per fixed-width time bin over [t0, t1).
    pub fn binned(&self, t0: f64, t1: f64, bins: usize) -> Vec<f64> {
        let mut sums = vec![0.0; bins];
        let mut counts = vec![0usize; bins];
        let w = (t1 - t0) / bins as f64;
        for &(t, v) in &self.points {
            if t < t0 || t >= t1 {
                continue;
            }
            let i = (((t - t0) / w) as usize).min(bins - 1);
            sums[i] += v;
            counts[i] += 1;
        }
        sums.iter()
            .zip(&counts)
            .map(|(&s, &c)| if c == 0 { 0.0 } else { s / c as f64 })
            .collect()
    }

    /// Count of points per bin (for arrival-rate plots).
    pub fn rate_binned(&self, t0: f64, t1: f64, bins: usize) -> Vec<f64> {
        let mut counts = vec![0.0; bins];
        let w = (t1 - t0) / bins as f64;
        for &(t, _) in &self.points {
            if t < t0 || t >= t1 {
                continue;
            }
            let i = (((t - t0) / w) as usize).min(bins - 1);
            counts[i] += 1.0;
        }
        counts
    }
}

/// Ordinary least squares via normal equations (XᵀX)β = Xᵀy with Gaussian
/// elimination + partial pivoting. Feature count is tiny (≤ 4: the
/// estimator fits α, β | γ, δ | λ), so this is exact enough and dependency
/// free.
pub fn least_squares(rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let n = rows.len();
    if n == 0 || n != y.len() {
        return None;
    }
    let k = rows[0].len();
    if k == 0 || rows.iter().any(|r| r.len() != k) {
        return None;
    }
    // Build normal equations A = XᵀX (k×k), b = Xᵀy.
    let mut a = vec![vec![0.0; k]; k];
    let mut b = vec![0.0; k];
    for (row, &yy) in rows.iter().zip(y) {
        for i in 0..k {
            b[i] += row[i] * yy;
            for j in 0..k {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    // Tikhonov jitter for singular designs.
    for i in 0..k {
        a[i][i] += 1e-12;
    }
    solve_dense(&mut a, &mut b)
}

/// In-place Gaussian elimination with partial pivoting; returns x solving Ax=b.
pub fn solve_dense(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-14 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        for r in col + 1..n {
            let f = a[r][col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for c in col + 1..n {
            s -= a[col][c] * x[c];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

/// Exponentially weighted moving average.
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let s = Summary::of(&xs);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn attainment() {
        let xs = [0.1, 0.2, 0.3, 0.4];
        assert!((Summary::attainment(&xs, 0.25) - 0.5).abs() < 1e-12);
        assert_eq!(Summary::attainment(&[], 1.0), 1.0);
    }

    #[test]
    fn sliding_window_evicts() {
        let mut w = SlidingWindow::new(10.0);
        for t in 0..20 {
            w.push(t as f64, t as f64);
        }
        assert!(w.len() <= 11);
        assert!(w.mean() > 12.0);
    }

    #[test]
    fn mu_plus_2sigma() {
        let mut w = SlidingWindow::new(1e9);
        for i in 0..1000 {
            w.push(i as f64, if i % 2 == 0 { 10.0 } else { 20.0 });
        }
        let v = w.mean_plus_k_sigma(2.0);
        assert!((v - 25.0).abs() < 0.1, "v={v}");
    }

    #[test]
    fn least_squares_recovers_line() {
        // y = 3x + 7
        let rows: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64, 1.0]).collect();
        let y: Vec<f64> = (0..50).map(|i| 3.0 * i as f64 + 7.0).collect();
        let beta = least_squares(&rows, &y).unwrap();
        assert!((beta[0] - 3.0).abs() < 1e-9);
        assert!((beta[1] - 7.0).abs() < 1e-9);
    }

    #[test]
    fn least_squares_quadratic() {
        // y = 2e-6 x^2 + 1e-3 x  (prefill-shaped, Eq. 6)
        let rows: Vec<Vec<f64>> = (1..100)
            .map(|i| {
                let l = (i * 50) as f64;
                vec![l * l, l]
            })
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| 2e-6 * r[0] + 1e-3 * r[1]).collect();
        let beta = least_squares(&rows, &y).unwrap();
        assert!((beta[0] - 2e-6).abs() < 1e-10);
        assert!((beta[1] - 1e-3).abs() < 1e-7);
    }

    #[test]
    fn timeseries_binning() {
        let mut ts = TimeSeries::default();
        for i in 0..100 {
            ts.push(i as f64, (i % 10) as f64);
        }
        let b = ts.binned(0.0, 100.0, 10);
        assert_eq!(b.len(), 10);
        assert!((b[0] - 4.5).abs() < 1e-12);
        let r = ts.rate_binned(0.0, 100.0, 4);
        assert_eq!(r, vec![25.0, 25.0, 25.0, 25.0]);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..30 {
            e.push(8.0);
        }
        assert!((e.get() - 8.0).abs() < 1e-6);
    }
}

//! Shared substrates built from scratch for the offline environment:
//! PRNG + distributions, JSON, statistics, CLI parsing, property testing.
pub mod ascii;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

//! Deterministic PRNG (SplitMix64 + xoshiro256**) and the distributions the
//! trace/workload generators need. `rand` is not available offline, so this
//! is a from-scratch substrate; all generators are seeded and reproducible.

/// xoshiro256** seeded via SplitMix64, plus sampling helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive. `lo <= hi` required.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        if span == 0 {
            // full range
            return self.next_u64();
        }
        // Lemire's method without rejection is fine for non-crypto use.
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)). Used for prompt/output length draws.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda). Inter-arrival times.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= f64::MIN_POSITIVE {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Poisson via Knuth (small lambda) or normal approximation (large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-like rank sampler over [0, n): P(i) ∝ 1/(i+1)^s.
    /// Used for skewed prefix-group popularity.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over precomputed-free harmonic approximation:
        // rejection-light approach is overkill here; do linear CDF walk for
        // small n and approximate inversion for large n.
        if n <= 64 {
            let mut weights = [0.0f64; 64];
            let mut total = 0.0;
            for (i, w) in weights.iter_mut().take(n).enumerate() {
                *w = 1.0 / ((i + 1) as f64).powf(s);
                total += *w;
            }
            let mut u = self.f64() * total;
            for (i, w) in weights.iter().take(n).enumerate() {
                u -= w;
                if u <= 0.0 {
                    return i;
                }
            }
            n - 1
        } else {
            // Approximate inverse CDF of the continuous analog.
            let u = self.f64();
            if (s - 1.0).abs() < 1e-9 {
                let hn = (n as f64).ln();
                ((u * hn).exp() - 1.0).min((n - 1) as f64) as usize
            } else {
                let a = 1.0 - s;
                let hn = ((n as f64).powf(a) - 1.0) / a;
                let x = (1.0 + u * hn * a).powf(1.0 / a) - 1.0;
                (x.min((n - 1) as f64)) as usize
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.range_u64(3, 17);
            assert!((3..=17).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(4);
        for &lambda in &[0.5, 4.0, 30.0, 200.0] {
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.poisson(lambda) as f64;
            }
            let mean = sum / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.08,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.exponential(2.0);
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn zipf_skew() {
        let mut r = Rng::new(6);
        let mut counts = vec![0usize; 10];
        for _ in 0..50_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[9]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Property-testing mini-framework (proptest is not reachable offline).
//!
//! A property is a closure over a seeded [`Gen`]; the harness runs it for N
//! random cases and, on failure, retries the failing seed with shrinking
//! *sizes* (the generator scales all magnitudes by `gen.size`), reporting
//! the smallest failing size and its seed so failures reproduce exactly.

use super::rng::Rng;

/// Random case generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Magnitude scale in (0, 1]; shrinking retries lower sizes.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    /// Integer in [lo, hi_max] where the effective hi shrinks with size.
    pub fn int(&mut self, lo: usize, hi_max: usize) -> usize {
        let hi = lo + (((hi_max - lo) as f64) * self.size).round() as usize;
        self.rng.range_usize(lo, hi.max(lo))
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.size * self.rng.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector with size-scaled length.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.int(0, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Outcome of a property: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Convenience: assert-like failure constructor.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Run `cases` random cases of the property. Panics (test failure) with the
/// reproducing seed + the failure of the smallest failing size.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    let base = 0x9E37_79B9_7F4A_7C15u64 ^ fnv(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x2545_F491_4F6C_DD1D));
        if let Err(msg) = prop(&mut Gen::new(seed, 1.0)) {
            // Shrink: retry the same seed at smaller sizes.
            let mut best = (1.0, msg);
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.02] {
                if let Err(m) = prop(&mut Gen::new(seed, size)) {
                    best = (size, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 smallest failing size {}):\n  {}",
                best.0, best.1
            );
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |g| {
            let a = g.f64(-100.0, 100.0);
            let b = g.f64(-100.0, 100.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-12, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check("always-fails", 5, |g| {
            let x = g.int(0, 10);
            prop_assert!(x > 100, "x={x} not > 100");
            Ok(())
        });
    }

    #[test]
    fn sizes_shrink_vectors() {
        let mut big = Gen::new(1, 1.0);
        let mut small = Gen::new(1, 0.05);
        let v_big: Vec<usize> = big.vec(1000, |g| g.int(0, 9));
        let v_small: Vec<usize> = small.vec(1000, |g| g.int(0, 9));
        assert!(v_small.len() <= v_big.len().max(60));
    }
}

//! Terminal plotting for the bench harness: bar charts and line series,
//! so `cargo bench` output mirrors the paper's figures without plotting
//! dependencies.

/// Horizontal bar chart: one labelled row per (label, value).
pub fn bar_chart(title: &str, rows: &[(String, f64)], unit: &str) -> String {
    let mut out = format!("\n== {title} ==\n");
    let max = rows.iter().map(|r| r.1).fold(0.0, f64::max).max(1e-12);
    let label_w = rows.iter().map(|r| r.0.len()).max().unwrap_or(4).max(4);
    for (label, v) in rows {
        let w = ((v / max) * 48.0).round() as usize;
        out.push_str(&format!(
            "{label:<label_w$} | {:<48} {v:.3} {unit}\n",
            "#".repeat(w)
        ));
    }
    out
}

/// Multi-series line plot over a shared x range, one braille-less char
/// canvas (rows = value axis, cols = time axis). Series are labelled with
/// distinct glyphs.
pub fn line_plot(
    title: &str,
    series: &[(&str, &[f64])],
    height: usize,
    y_label: &str,
) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '@', '~'];
    let width = series.iter().map(|s| s.1.len()).max().unwrap_or(0);
    if width == 0 {
        return format!("\n== {title} == (no data)\n");
    }
    let max = series
        .iter()
        .flat_map(|s| s.1.iter())
        .cloned()
        .fold(0.0, f64::max)
        .max(1e-12);
    let mut canvas = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (x, &y) in ys.iter().enumerate() {
            let row = ((1.0 - (y / max).clamp(0.0, 1.0)) * (height - 1) as f64).round() as usize;
            canvas[row][x] = GLYPHS[si % GLYPHS.len()];
        }
    }
    let mut out = format!("\n== {title} ==  (y max = {max:.3} {y_label})\n");
    for (i, row) in canvas.iter().enumerate() {
        let margin = if i == 0 {
            format!("{max:>9.2} ")
        } else if i == height - 1 {
            format!("{:>9.2} ", 0.0)
        } else {
            " ".repeat(10)
        };
        out.push_str(&margin);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&" ".repeat(10));
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str("  legend: ");
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={}  ", GLYPHS[si % GLYPHS.len()], name));
    }
    out.push('\n');
    out
}

/// Fixed-width table.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = format!("\n== {title} ==\n");
    let line = |cells: &[String], widths: &[usize]| {
        let mut s = String::new();
        for (c, w) in cells.iter().zip(widths) {
            s.push_str(&format!("{c:<w$}  "));
        }
        s.trim_end().to_string() + "\n"
    };
    out.push_str(&line(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push_str(&format!(
        "{}\n",
        widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>()
    ));
    for row in rows {
        out.push_str(&line(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_chart_renders() {
        let s = bar_chart("t", &[("a".into(), 1.0), ("bb".into(), 2.0)], "x");
        assert!(s.contains("bb"));
        assert!(s.contains("####"));
    }

    #[test]
    fn line_plot_renders() {
        let ys: Vec<f64> = (0..50).map(|i| (i as f64 / 5.0).sin().abs()).collect();
        let s = line_plot("t", &[("sin", &ys)], 8, "u");
        assert!(s.contains("legend"));
        assert!(s.matches('\n').count() > 8);
    }

    #[test]
    fn table_renders() {
        let s = table("t", &["a", "b"], &[vec!["1".into(), "22".into()]]);
        assert!(s.contains("22"));
    }
}

//! Tiny CLI argument parser (clap is not reachable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generated `--help` text. Each binary declares its options up front so
//! help stays accurate.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    pub program: String,
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

pub struct Cli {
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Cli {
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self, program: &str) -> String {
        let mut s = format!("{}\n\nUsage: {} [options] [args]\n\nOptions:\n", self.about, program);
        for o in &self.opts {
            let lhs = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = o
                .default
                .filter(|d| !d.is_empty())
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{lhs:28} {}{def}\n", o.help));
        }
        s.push_str("  --help                     show this help\n");
        s
    }

    /// Parse an iterator of args (excluding argv[0] handled by caller).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        &self,
        program: &str,
        argv: I,
    ) -> Result<Args, String> {
        let mut out = Args {
            program: program.to_string(),
            ..Default::default()
        };
        for o in &self.opts {
            if let (Some(d), false) = (o.default, o.is_flag) {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage(program));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage(program)))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    out.flags.insert(key, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?,
                    };
                    out.values.insert(key, v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn parse_env(&self) -> Result<Args, String> {
        let mut argv = std::env::args();
        let program = argv.next().unwrap_or_else(|| "echo".into());
        self.parse_from(&program, argv)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str) -> String {
        self.get(key).unwrap_or_default().to_string()
    }

    pub fn f64(&self, key: &str) -> Result<f64, String> {
        self.str(key)
            .parse()
            .map_err(|_| format!("--{key}: expected a number, got {:?}", self.str(key)))
    }

    pub fn usize(&self, key: &str) -> Result<usize, String> {
        self.str(key)
            .parse()
            .map_err(|_| format!("--{key}: expected an integer, got {:?}", self.str(key)))
    }

    pub fn u64(&self, key: &str) -> Result<u64, String> {
        self.str(key)
            .parse()
            .map_err(|_| format!("--{key}: expected an integer, got {:?}", self.str(key)))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test")
            .opt("rate", "1.5", "arrival rate")
            .opt("out", "", "output path")
            .flag("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Args, String> {
        cli().parse_from("t", args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.f64("rate").unwrap(), 1.5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn value_forms() {
        let a = parse(&["--rate", "2.0", "--out=x.json", "--verbose", "pos1"]).unwrap();
        assert_eq!(a.f64("rate").unwrap(), 2.0);
        assert_eq!(a.str("out"), "x.json");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--nope"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--rate"]).is_err());
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = parse(&["--help"]).unwrap_err();
        assert!(e.contains("--rate"));
    }

    #[test]
    fn bad_number_reported() {
        let a = parse(&["--rate", "abc"]).unwrap();
        assert!(a.f64("rate").is_err());
    }
}

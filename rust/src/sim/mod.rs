//! Resource & throughput simulator for system deployers (paper §5.4).
//!
//! Step 1 — peak-window resource estimation: replay a short window around
//! the online trace's peak against increasing KV capacity until the online
//! SLO attainment target is met (no offline load).
//!
//! Step 2 — offline throughput estimation: with chosen resources, replay a
//! long horizon with the offline backlog co-scheduled and report the
//! achievable offline token throughput.

use anyhow::Result;

use crate::config::SystemConfig;
use crate::core::{PromptSpec, RequestStore, TaskClass};
use crate::engine::{sim::SimBackend, Engine};
use crate::estimator::TimeModel;
use crate::serve::{EngineServe, NullSink, Serve, SubmitSpec};
use crate::trace::Trace;
use crate::utils::rng::Rng;
use crate::workload::{synthesize, DatasetSpec};

#[derive(Clone, Debug)]
pub struct DeployerReport {
    /// Smallest KV capacity (tokens) meeting the SLO target at peak.
    pub min_capacity_tokens: usize,
    /// Capacities probed: (capacity, ttft attainment, token attainment).
    pub probes: Vec<(usize, f64, f64)>,
    /// Offline throughput (tokens/s) at the chosen capacity (step 2).
    pub offline_throughput: f64,
    /// Online attainment at the chosen capacity with offline co-scheduled.
    pub online_attainment: (f64, f64),
}

pub struct DeployerSim {
    pub cfg: SystemConfig,
    /// Target attainment (paper eval: 0.9).
    pub target: f64,
    pub online_spec: DatasetSpec,
}

impl DeployerSim {
    pub fn new(cfg: SystemConfig) -> Self {
        DeployerSim {
            cfg,
            target: 0.9,
            online_spec: DatasetSpec::sharegpt(),
        }
    }

    fn build_engine(&self, capacity: usize, seed: u64) -> Engine<SimBackend> {
        let mut cfg = self.cfg.clone();
        cfg.cache.capacity_tokens = capacity;
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), seed, 0.02);
        Engine::new(cfg, backend)
    }

    /// Step 1: smallest capacity meeting the SLO target over the peak
    /// window (doubling then bisection).
    pub fn min_resources_at_peak(&self, peak_arrivals: &[f64]) -> Result<(usize, Vec<(usize, f64, f64)>)> {
        let mut probes = Vec::new();
        let run = |capacity: usize| -> Result<(f64, f64)> {
            let mut front = EngineServe::new(self.build_engine(capacity, 7));
            let mut rng = Rng::new(13);
            // Submit online requests along the window.
            for &t in peak_arrivals {
                let (prompt, out) = rng_prompt(&self.online_spec, &mut rng);
                front.submit(SubmitSpec::online(prompt, out).at(t))?;
            }
            front.drain(&mut NullSink)?;
            let e = front.into_engine();
            Ok(e.metrics.slo_attainment(&e.cfg.slo))
        };
        // Doubling search.
        let mut lo = self.cfg.cache.block_size * 64;
        let mut hi = lo;
        loop {
            let (a_ttft, a_tok) = run(hi)?;
            probes.push((hi, a_ttft, a_tok));
            if a_ttft >= self.target && a_tok >= self.target {
                break;
            }
            hi *= 2;
            if hi > 100_000_000 {
                anyhow::bail!("no capacity meets the SLO target (workload too hot)");
            }
        }
        // Bisection between hi/2 and hi.
        lo = hi / 2;
        while hi - lo > self.cfg.cache.block_size * 64 {
            let mid = (lo + hi) / 2;
            let (a_ttft, a_tok) = run(mid)?;
            probes.push((mid, a_ttft, a_tok));
            if a_ttft >= self.target && a_tok >= self.target {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        Ok((hi, probes))
    }

    /// Step 2: offline throughput over a horizon at fixed capacity.
    pub fn offline_throughput(
        &self,
        capacity: usize,
        arrivals: &[f64],
        offline_spec: &DatasetSpec,
        n_offline: usize,
        horizon: f64,
    ) -> Result<(f64, (f64, f64))> {
        let mut front = EngineServe::new(self.build_engine(capacity, 11));
        let mut rng = Rng::new(17);
        for &t in arrivals {
            let (prompt, out) = rng_prompt(&self.online_spec, &mut rng);
            front.submit(SubmitSpec::online(prompt, out).at(t))?;
        }
        let mut scratch = RequestStore::new();
        let batch = synthesize(
            offline_spec,
            n_offline,
            TaskClass::Offline,
            0.0,
            &mut scratch,
            &mut rng,
        );
        for &id in &batch.ids {
            let r = scratch.get(id);
            front.submit(SubmitSpec::offline(r.prompt.clone(), r.max_new_tokens))?;
        }
        front.run_until(horizon, &mut NullSink)?;
        let e = front.into_engine();
        Ok((
            e.metrics.offline_tokens_out as f64 / e.clock.max(1e-9),
            e.metrics.slo_attainment(&e.cfg.slo),
        ))
    }

    /// Full §5.4 report over a trace.
    pub fn report(
        &self,
        trace: &Trace,
        peak_window: (f64, f64),
        offline_spec: &DatasetSpec,
        n_offline: usize,
        horizon: f64,
    ) -> Result<DeployerReport> {
        let peak: Vec<f64> = trace
            .arrivals
            .iter()
            .copied()
            .filter(|&t| t >= peak_window.0 && t < peak_window.1)
            .map(|t| t - peak_window.0)
            .collect();
        let (min_cap, probes) = self.min_resources_at_peak(&peak)?;
        let (thr, attain) =
            self.offline_throughput(min_cap.max(self.cfg.cache.capacity_tokens), &trace.arrivals, offline_spec, n_offline, horizon)?;
        Ok(DeployerReport {
            min_capacity_tokens: min_cap,
            probes,
            offline_throughput: thr,
            online_attainment: attain,
        })
    }
}

fn rng_prompt(spec: &DatasetSpec, rng: &mut Rng) -> (PromptSpec, usize) {
    // Single-request draw mirroring workload::synthesize's marginals.
    let mu = (spec.mean_prompt as f64).ln() - spec.prompt_sigma * spec.prompt_sigma / 2.0;
    let len = (rng.lognormal(mu, spec.prompt_sigma).round() as usize).clamp(2, spec.mean_prompt * 8);
    let mu_o = (spec.mean_out as f64).ln() - spec.out_sigma * spec.out_sigma / 2.0;
    let out = (rng.lognormal(mu_o, spec.out_sigma).round() as usize).clamp(2, spec.mean_out * 8);
    (PromptSpec::sim(len, None), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceConfig;

    #[test]
    fn step1_finds_minimal_capacity() {
        let mut cfg = SystemConfig::a100_llama8b();
        cfg.scheduler.max_batch = 32;
        let sim = DeployerSim::new(cfg);
        // Modest peak: 1 req every 2 s for 60 s.
        let peak: Vec<f64> = (0..30).map(|i| i as f64 * 2.0).collect();
        let (cap, probes) = sim.min_resources_at_peak(&peak).unwrap();
        assert!(cap >= 1024, "cap {cap}");
        assert!(!probes.is_empty());
        // The chosen capacity meets the target; the probe just below (if
        // recorded as failing) does not.
        let ok = probes.iter().find(|&&(c, a, b)| c == cap && a >= 0.9 && b >= 0.9);
        assert!(ok.is_some());
    }

    #[test]
    fn step2_reports_positive_offline_throughput() {
        let cfg = SystemConfig::a100_llama8b();
        let sim = DeployerSim::new(cfg);
        let tr = Trace::generate(&TraceConfig::compressed(120.0, 0.3, 5));
        let (thr, (a_ttft, _)) = sim
            .offline_throughput(
                100_000,
                &tr.arrivals,
                &DatasetSpec::loogle_qa_short().scaled(0.05),
                40,
                400.0,
            )
            .unwrap();
        assert!(thr > 0.0, "thr {thr}");
        assert!(a_ttft >= 0.9, "ttft attainment {a_ttft}");
    }
}

//! SLO guard (PR 9): measured-latency feedback control for co-located
//! serving.
//!
//! Echo's admission control is *predictive* — the Eq. 6–8 estimator gates
//! offline work before it runs. A mispredicted burst, estimator drift, or
//! a fault-recovery recompute storm (PR 7) can still blow p99 TTFT with no
//! corrective path. Following HyGen's measured-latency feedback loop and
//! ConServe's fast-reclamation granularity (PAPERS.md), this module closes
//! the loop from *measured* windowed attainment back to scheduling
//! decisions, entirely on the virtual clock:
//!
//! * **Window** — sliding p50/p99 TTFT/TPOT attainment over the last `W`
//!   seconds, via [`WindowedHist`] snapshot deltas of the cumulative PR 6
//!   histograms (fleet-summed, so the signal is the true pooled window).
//! * **AIMD offline budget** — a tokens-per-batch cap on offline work the
//!   scheduler must respect: additive increase while the window attains,
//!   multiplicative decrease the moment it does not.
//! * **Brownout ladder** — Normal → PauseOfflineAdmission →
//!   DrainOfflineRunning → ShedNewOffline → Emergency (preempt all
//!   offline), with hysteresis: escalation needs a short hold at the
//!   current rung, de-escalation needs sustained recovery for at least a
//!   full window (`min_dwell` is clamped to ≥ `window`), so the ladder
//!   never round-trips Normal → Pause → Normal inside one window.
//!
//! The controller ticks once per sync quantum in the cluster coordinator
//! phase (strictly single-threaded), so an armed guard is bit-exact across
//! `--threads`; disarmed, the fleet carries no guard state at all and every
//! engine-side actuator is an untaken comparison.
//!
//! An empty window (no online samples in the last `W` seconds) counts as
//! vacuously attained. This is deliberate: a browned-out fleet whose online
//! traffic has gone quiet *must* ratchet back up — otherwise a paused
//! backlog could never drain and the stall detector's paused-by-policy
//! exemption (see `serve::ClusterServe`) would turn into a real hang.

use crate::core::Slo;
use crate::metrics::{Metrics, WindowedHist};
use crate::utils::json::Json;
use crate::utils::stats::LogHistogram;

/// Brownout rungs, mildest to harshest. Each rung implies every milder
/// rung's actuators.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum BrownoutLevel {
    /// Full co-location: offline admission and execution unconstrained
    /// (beyond the AIMD token cap, which stays at its ceiling while the
    /// window attains).
    #[default]
    Normal,
    /// The fleet stops feeding new offline work from the shared backlog to
    /// replica pools (work-stealing pauses); already-dispatched offline
    /// work keeps running.
    PauseOfflineAdmission,
    /// Replicas additionally stop admitting new offline requests from
    /// their local pools; resident offline work drains to completion.
    DrainOfflineRunning,
    /// New offline submits at the serve front door are rejected with typed
    /// backpressure (`Retry` with a `retry_after` hint).
    ShedNewOffline,
    /// Preempt every running offline request fleet-wide and schedule zero
    /// offline tokens; new offline submits are shed outright.
    Emergency,
}

impl BrownoutLevel {
    pub fn as_u8(self) -> u8 {
        match self {
            BrownoutLevel::Normal => 0,
            BrownoutLevel::PauseOfflineAdmission => 1,
            BrownoutLevel::DrainOfflineRunning => 2,
            BrownoutLevel::ShedNewOffline => 3,
            BrownoutLevel::Emergency => 4,
        }
    }

    pub fn from_u8(v: u8) -> BrownoutLevel {
        match v {
            0 => BrownoutLevel::Normal,
            1 => BrownoutLevel::PauseOfflineAdmission,
            2 => BrownoutLevel::DrainOfflineRunning,
            3 => BrownoutLevel::ShedNewOffline,
            _ => BrownoutLevel::Emergency,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BrownoutLevel::Normal => "normal",
            BrownoutLevel::PauseOfflineAdmission => "pause_offline_admission",
            BrownoutLevel::DrainOfflineRunning => "drain_offline_running",
            BrownoutLevel::ShedNewOffline => "shed_new_offline",
            BrownoutLevel::Emergency => "emergency",
        }
    }

    fn up(self) -> BrownoutLevel {
        BrownoutLevel::from_u8((self.as_u8() + 1).min(4))
    }

    fn down(self) -> BrownoutLevel {
        BrownoutLevel::from_u8(self.as_u8().saturating_sub(1))
    }
}

/// Control-law knobs. Defaults target the paper-eval SLO regime; every
/// field is virtual-clock seconds or tokens.
#[derive(Clone, Copy, Debug)]
pub struct SloGuardConfig {
    /// Escalate (and multiplicatively cut the cap) when the windowed
    /// attainment falls below this.
    pub target: f64,
    /// De-escalate (and additively grow the cap) when the windowed
    /// attainment is at or above this. Must be ≥ `target` (hysteresis gap).
    pub recover: f64,
    /// Sliding-window width, seconds.
    pub window: f64,
    /// Minimum time at a rung before de-escalating; clamped to ≥ `window`
    /// at construction so the ladder cannot round-trip inside one window.
    pub min_dwell: f64,
    /// Minimum time at a rung before escalating further (lets an actuator
    /// take effect before the next rung piles on).
    pub escalate_hold: f64,
    /// AIMD additive increase per tick, tokens.
    pub cap_increase: usize,
    /// AIMD floor: the offline token cap never drops below this outside
    /// Emergency (a trickle keeps resident offline work drainable).
    pub cap_min: usize,
    /// AIMD ceiling (and starting value): typically the scheduler's
    /// `max_batched_tokens`, i.e. "uncapped".
    pub cap_max: usize,
}

impl Default for SloGuardConfig {
    fn default() -> Self {
        SloGuardConfig {
            target: 0.9,
            recover: 0.95,
            window: 10.0,
            min_dwell: 10.0,
            escalate_hold: 0.5,
            cap_increase: 64,
            cap_min: 16,
            cap_max: 2048,
        }
    }
}

/// One tick's actuator outputs. `Default` is the disarmed state: Normal,
/// uncapped, nothing paused or shed — `ClusterSim` hands this out when no
/// guard is configured, so downstream consumers never branch on an
/// `Option`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GuardDecision {
    pub level: BrownoutLevel,
    /// Offline tokens-per-batch cap for replica schedulers
    /// (`usize::MAX` = uncapped, 0 = no offline tokens at all).
    pub offline_cap: usize,
    /// Gate the backlog → replica-pool feed (work-stealing).
    pub pause_admission: bool,
    /// Block new offline admissions inside replica schedulers.
    pub drain_running: bool,
    /// Reject new offline submits at the front door.
    pub shed_new: bool,
    /// Preempt all running offline work this quantum.
    pub emergency: bool,
    /// Wire backpressure hint, seconds: earliest instant the ladder could
    /// de-escalate below the shedding rung.
    pub retry_after: f64,
    /// The level changed on this tick (transition edge, for tracing).
    pub changed: bool,
}

impl Default for GuardDecision {
    fn default() -> Self {
        GuardDecision {
            level: BrownoutLevel::Normal,
            offline_cap: usize::MAX,
            pause_admission: false,
            drain_running: false,
            shed_new: false,
            emergency: false,
            retry_after: 0.0,
            changed: false,
        }
    }
}

impl GuardDecision {
    /// Per-replica headroom split of the fleet cap: a replica with online
    /// work waiting in its admission queue has no harvest headroom and
    /// gets half the budget; an idle-online replica gets the full cap.
    /// Deterministic pure function of coordinator-phase state.
    pub fn replica_cap(&self, queued_online: usize) -> usize {
        if self.emergency {
            return 0;
        }
        if self.offline_cap == usize::MAX {
            return usize::MAX;
        }
        if queued_online == 0 {
            self.offline_cap
        } else {
            (self.offline_cap / 2).max(1)
        }
    }
}

/// Controller telemetry, surfaced in the cluster report. Counters owned by
/// the guard are updated in `tick`; `shed_submits`/`retry_submits`/
/// `emergency_preempted` are credited by the front door / coordinator.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GuardStats {
    /// Ladder transitions (either direction).
    pub transitions: u64,
    pub escalations: u64,
    pub deescalations: u64,
    /// Ticks spent at PauseOfflineAdmission or above. Also the
    /// paused-by-policy progress counter the stall detector consumes.
    pub pause_ticks: u64,
    /// Running offline requests preempted by Emergency rungs.
    pub emergency_preempted: u64,
    /// Offline submits rejected with `Retry` backpressure.
    pub retry_submits: u64,
    /// Offline submits shed outright.
    pub shed_submits: u64,
    /// Ticks spent inside a churn-exclusion grace window (quarantine
    /// respawns, PR 10): escalation and cap cuts are suspended so the
    /// ladder judges steady-state traffic, not recovery recompute.
    pub suspended_ticks: u64,
    /// Most recent windowed attainment (min of TTFT and TPOT windows).
    pub last_attainment: f64,
    /// Most recent AIMD cap.
    pub cap: usize,
}

impl GuardStats {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("transitions", self.transitions)
            .set("escalations", self.escalations)
            .set("deescalations", self.deescalations)
            .set("pause_ticks", self.pause_ticks)
            .set("emergency_preempted", self.emergency_preempted)
            .set("retry_submits", self.retry_submits)
            .set("shed_submits", self.shed_submits)
            .set("suspended_ticks", self.suspended_ticks)
            .set("last_attainment", self.last_attainment)
            .set("offline_cap", if self.cap == usize::MAX { 0 } else { self.cap as u64 })
    }
}

/// The deterministic feedback controller. One instance per fleet, ticked
/// at quantum boundaries in the single-threaded coordinator phase.
#[derive(Clone, Debug)]
pub struct SloGuard {
    cfg: SloGuardConfig,
    slo: Slo,
    level: BrownoutLevel,
    /// Virtual time the current level was entered.
    entered_at: f64,
    /// AIMD offline token cap.
    cap: usize,
    ttft_win: WindowedHist,
    tpot_win: WindowedHist,
    /// Fleet-summed cumulative bucket counts, recycled every tick.
    scratch_ttft: Vec<u64>,
    scratch_tpot: Vec<u64>,
    /// Churn-exclusion deadline (PR 10): until this instant, misses do not
    /// escalate the ladder or cut the AIMD cap. Recovery (de-escalation,
    /// cap growth) is never suspended, so the grace window can only make
    /// the guard *milder* — it cannot deadlock the ladder.
    suspended_until: f64,
    pub stats: GuardStats,
    last: GuardDecision,
}

impl SloGuard {
    /// `dt` is the tick cadence (the cluster sync quantum) — it sizes the
    /// snapshot ring and floors the `retry_after` hint.
    pub fn new(mut cfg: SloGuardConfig, slo: Slo, dt: f64) -> Self {
        cfg.min_dwell = cfg.min_dwell.max(cfg.window);
        cfg.recover = cfg.recover.max(cfg.target);
        cfg.cap_min = cfg.cap_min.min(cfg.cap_max).max(1);
        let cap = cfg.cap_max;
        SloGuard {
            cfg,
            slo,
            level: BrownoutLevel::Normal,
            entered_at: 0.0,
            cap,
            ttft_win: WindowedHist::new(cfg.window, dt),
            tpot_win: WindowedHist::new(cfg.window, dt),
            scratch_ttft: vec![0u64; LogHistogram::BUCKETS],
            scratch_tpot: vec![0u64; LogHistogram::BUCKETS],
            suspended_until: 0.0,
            stats: GuardStats {
                cap,
                last_attainment: 1.0,
                ..GuardStats::default()
            },
            last: GuardDecision::default(),
        }
    }

    pub fn config(&self) -> &SloGuardConfig {
        &self.cfg
    }

    pub fn level(&self) -> BrownoutLevel {
        self.level
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// The most recent decision (what `tick` last returned).
    pub fn decision(&self) -> GuardDecision {
        self.last
    }

    /// Open (or extend) a churn-exclusion grace window: until `until`,
    /// windowed misses neither escalate the ladder nor cut the AIMD cap.
    /// Called by the coordinator when quarantine respawns inject recompute
    /// latency that says nothing about offline pressure (PR 10).
    /// Max-accumulates, so overlapping quarantines extend rather than
    /// truncate the window; de-escalation is unaffected (no deadlock).
    pub fn exclude_churn_until(&mut self, until: f64) {
        self.suspended_until = self.suspended_until.max(until);
    }

    /// Windowed attainment pair (TTFT, TPOT) as of the last tick.
    pub fn window_attainment(&self) -> (f64, f64) {
        (
            self.ttft_win.attainment(self.slo.ttft),
            self.tpot_win.attainment(self.slo.tpot),
        )
    }

    /// Windowed latency percentile pair (TTFT p, TPOT p) as of the last
    /// tick — telemetry for reports and figures.
    pub fn window_percentile(&self, p: f64) -> (f64, f64) {
        (self.ttft_win.percentile(p), self.tpot_win.percentile(p))
    }

    /// One controller tick at virtual time `now`: fold the fleet's
    /// cumulative latency histograms (live replicas + retired corpses —
    /// cumulative snapshots must never go backwards), advance the window,
    /// run the AIMD law and the ladder, and return the actuator set.
    /// Allocation-free in steady state (scratch and window rings are
    /// pre-sized); called only from the single-threaded coordinator phase,
    /// so an armed guard stays bit-exact across `--threads`.
    // lint: hot-path
    pub fn tick<'a>(
        &mut self,
        now: f64,
        parts: impl Iterator<Item = &'a Metrics>,
    ) -> GuardDecision {
        // ---- 1. fleet-summed cumulative snapshots -----------------------
        self.scratch_ttft.fill(0);
        self.scratch_tpot.fill(0);
        for m in parts {
            for (i, &c) in m.ttft_hist.bucket_counts().iter().enumerate() {
                self.scratch_ttft[i] += c;
            }
            for (i, &c) in m.tpot_hist.bucket_counts().iter().enumerate() {
                self.scratch_tpot[i] += c;
            }
        }
        self.ttft_win.push(now, &self.scratch_ttft);
        self.tpot_win.push(now, &self.scratch_tpot);

        // ---- 2. pressure signal ----------------------------------------
        let att_ttft = self.ttft_win.attainment(self.slo.ttft);
        let att_tpot = self.tpot_win.attainment(self.slo.tpot);
        let att = att_ttft.min(att_tpot);
        self.stats.last_attainment = att;
        // Churn exclusion (PR 10): inside the grace window misses are
        // attributed to quarantine respawn churn, so only the *mildening*
        // halves of the control laws run.
        let suspended = now < self.suspended_until;
        if suspended {
            self.stats.suspended_ticks += 1;
        }

        // ---- 3. AIMD offline token budget ------------------------------
        if att < self.cfg.target {
            if !suspended {
                self.cap = (self.cap / 2).max(self.cfg.cap_min);
            }
        } else if att >= self.cfg.recover {
            self.cap = self.cap.saturating_add(self.cfg.cap_increase).min(self.cfg.cap_max);
        }
        self.stats.cap = self.cap;

        // ---- 4. brownout ladder with hysteresis ------------------------
        let dwelled = now - self.entered_at;
        let prev = self.level;
        if att < self.cfg.target
            && !suspended
            && self.level < BrownoutLevel::Emergency
            && (self.level == BrownoutLevel::Normal || dwelled >= self.cfg.escalate_hold)
        {
            self.level = self.level.up();
        } else if att >= self.cfg.recover
            && self.level > BrownoutLevel::Normal
            && dwelled >= self.cfg.min_dwell
        {
            self.level = self.level.down();
        }
        if self.level != prev {
            self.entered_at = now;
            self.stats.transitions += 1;
            if self.level > prev {
                self.stats.escalations += 1;
            } else {
                self.stats.deescalations += 1;
            }
        }
        if self.level >= BrownoutLevel::PauseOfflineAdmission {
            self.stats.pause_ticks += 1;
        }

        // ---- 5. actuator set -------------------------------------------
        let emergency = self.level == BrownoutLevel::Emergency;
        self.last = GuardDecision {
            level: self.level,
            offline_cap: if emergency { 0 } else { self.cap },
            pause_admission: self.level >= BrownoutLevel::PauseOfflineAdmission,
            drain_running: self.level >= BrownoutLevel::DrainOfflineRunning,
            shed_new: self.level >= BrownoutLevel::ShedNewOffline,
            emergency,
            retry_after: (self.entered_at + self.cfg.min_dwell - now)
                .max(self.ttft_win.window() * 0.1),
            changed: self.level != prev,
        };
        self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskClass;

    fn guard(window: f64, dt: f64) -> SloGuard {
        let cfg = SloGuardConfig {
            window,
            min_dwell: window,
            escalate_hold: dt,
            ..SloGuardConfig::default()
        };
        SloGuard::new(cfg, Slo::paper_eval(), dt)
    }

    /// Feed `n` online completions with the given TTFT/TPOT into `m`.
    fn feed(m: &mut Metrics, n: usize, ttft: f64, tpot: f64) {
        for _ in 0..n {
            m.record_completion(TaskClass::Online, 8, 100, Some(ttft), Some(tpot));
        }
    }

    #[test]
    fn ladder_escalates_under_misses_and_recovers_with_dwell() {
        let mut g = guard(4.0, 1.0);
        let mut m = Metrics::default();
        let mut t = 0.0;
        // Healthy traffic: stays Normal, cap at ceiling.
        for _ in 0..5 {
            feed(&mut m, 4, 0.2, 0.01);
            t += 1.0;
            let d = g.tick(t, std::iter::once(&m));
            assert_eq!(d.level, BrownoutLevel::Normal);
            assert!(!d.pause_admission);
        }
        assert_eq!(g.cap(), g.config().cap_max);
        // Sustained misses: ladder climbs one rung per tick (after the
        // hold), cap halves toward the floor.
        for _ in 0..6 {
            feed(&mut m, 4, 5.0, 0.01);
            t += 1.0;
            g.tick(t, std::iter::once(&m));
        }
        assert_eq!(g.level(), BrownoutLevel::Emergency);
        assert_eq!(g.cap(), g.config().cap_min);
        let d = g.decision();
        assert!(d.pause_admission && d.drain_running && d.shed_new && d.emergency);
        assert_eq!(d.offline_cap, 0);
        assert!(d.retry_after > 0.0);
        // Traffic goes quiet: the window empties (vacuous attainment) and
        // the ladder ratchets all the way back down, one dwell per rung.
        for _ in 0..40 {
            t += 1.0;
            g.tick(t, std::iter::once(&m));
        }
        assert_eq!(g.level(), BrownoutLevel::Normal);
        assert!(g.stats.deescalations >= 4);
        assert_eq!(g.decision().offline_cap, g.cap());
    }

    #[test]
    fn hysteresis_blocks_round_trip_within_one_window() {
        let mut g = guard(6.0, 1.0);
        let mut m = Metrics::default();
        let mut t = 0.0;
        // One bad burst, then immediately perfect traffic again.
        feed(&mut m, 10, 5.0, 0.01);
        t += 1.0;
        let d = g.tick(t, std::iter::once(&m));
        assert_eq!(d.level, BrownoutLevel::PauseOfflineAdmission);
        let entered = t;
        loop {
            feed(&mut m, 10, 0.1, 0.01);
            t += 1.0;
            let d = g.tick(t, std::iter::once(&m));
            if d.level == BrownoutLevel::Normal {
                break;
            }
            assert!(t < 60.0, "must eventually recover");
        }
        // De-escalation can only have happened after a full dwell >= window.
        assert!(t - entered >= g.config().min_dwell - 1e-9);
        assert!(g.config().min_dwell >= g.config().window);
    }

    #[test]
    fn aimd_cap_halves_and_regrows() {
        let mut g = guard(4.0, 1.0);
        let mut m = Metrics::default();
        let mut t = 0.0;
        feed(&mut m, 10, 5.0, 0.01);
        t += 1.0;
        g.tick(t, std::iter::once(&m));
        assert_eq!(g.cap(), g.config().cap_max / 2);
        feed(&mut m, 10, 5.0, 0.01);
        t += 1.0;
        g.tick(t, std::iter::once(&m));
        assert_eq!(g.cap(), g.config().cap_max / 4);
        // Recovery: additive regrowth, never past the ceiling.
        for _ in 0..200 {
            feed(&mut m, 40, 0.1, 0.01);
            t += 1.0;
            g.tick(t, std::iter::once(&m));
        }
        assert_eq!(g.cap(), g.config().cap_max);
    }

    #[test]
    fn churn_exclusion_suspends_escalation_but_not_recovery() {
        let mut g = guard(4.0, 1.0);
        let mut m = Metrics::default();
        let mut t = 0.0;
        // Escalate once so there is something to recover from.
        feed(&mut m, 10, 5.0, 0.01);
        t += 1.0;
        g.tick(t, std::iter::once(&m));
        assert_eq!(g.level(), BrownoutLevel::PauseOfflineAdmission);
        let cap_after_cut = g.cap();
        // Grace window: further misses neither climb the ladder nor cut
        // the AIMD cap.
        g.exclude_churn_until(t + 10.0);
        for _ in 0..5 {
            feed(&mut m, 10, 5.0, 0.01);
            t += 1.0;
            g.tick(t, std::iter::once(&m));
        }
        assert_eq!(g.level(), BrownoutLevel::PauseOfflineAdmission);
        assert_eq!(g.cap(), cap_after_cut);
        assert!(g.stats.suspended_ticks >= 5, "{:?}", g.stats);
        // Clean traffic de-escalates *inside* the window (recovery is
        // never suspended) once the dwell elapses.
        loop {
            feed(&mut m, 20, 0.1, 0.01);
            t += 1.0;
            g.exclude_churn_until(t + 5.0);
            if g.tick(t, std::iter::once(&m)).level == BrownoutLevel::Normal {
                break;
            }
            assert!(t < 60.0, "recovery must not deadlock under churn exclusion");
        }
    }

    #[test]
    fn replica_cap_splits_on_online_pressure() {
        let d = GuardDecision {
            offline_cap: 100,
            ..GuardDecision::default()
        };
        assert_eq!(d.replica_cap(0), 100);
        assert_eq!(d.replica_cap(3), 50);
        let un = GuardDecision::default();
        assert_eq!(un.replica_cap(5), usize::MAX);
        let em = GuardDecision {
            emergency: true,
            ..GuardDecision::default()
        };
        assert_eq!(em.replica_cap(0), 0);
    }

    #[test]
    fn disarmed_default_decision_is_inert() {
        let d = GuardDecision::default();
        assert_eq!(d.level, BrownoutLevel::Normal);
        assert_eq!(d.offline_cap, usize::MAX);
        assert!(!d.pause_admission && !d.drain_running && !d.shed_new && !d.emergency);
    }

    #[test]
    fn level_round_trips_through_u8() {
        for v in 0..=4u8 {
            assert_eq!(BrownoutLevel::from_u8(v).as_u8(), v);
        }
        assert!(BrownoutLevel::Normal < BrownoutLevel::Emergency);
    }
}

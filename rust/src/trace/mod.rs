//! Online-arrival trace generation and replay (paper §2.2, Fig. 2).
//!
//! The paper uses a proprietary 24-hour provider trace with two stated
//! properties: a *tidal* pattern (peak 12:00-14:00, trough 04:00-06:00,
//! peak/trough ≈ 6×) and short-scale *burstiness*. We synthesize the same
//! shape: a sinusoid-of-day base rate modulated by a 2-state MMPP
//! (Markov-modulated Poisson process) whose burst state multiplies the
//! rate. Arrival times come from Lewis thinning, so any non-negative
//! rate function is supported. Traces are reproducible (seeded) and can be
//! scaled to the testbed capacity like the paper does (§7.1).

use crate::utils::json::Json;
use crate::utils::rng::Rng;

pub const DAY: f64 = 86_400.0;

#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Trace horizon in seconds.
    pub horizon: f64,
    /// Mean arrival rate (req/s) averaged over the tide.
    pub mean_rate: f64,
    /// Peak-to-trough ratio of the tidal pattern (paper: ≈ 6).
    pub tidal_ratio: f64,
    /// Hour of day (0-24) of the tidal peak (paper: ~13:00).
    pub peak_hour: f64,
    /// Period of the tide in seconds (DAY, or compressed for fast runs).
    pub period: f64,
    /// Burst state rate multiplier.
    pub burst_mult: f64,
    /// Mean sojourn in burst / calm states (seconds).
    pub burst_mean: f64,
    pub calm_mean: f64,
    pub seed: u64,
}

impl TraceConfig {
    /// Paper-shaped 24 h trace.
    pub fn paper_24h(mean_rate: f64, seed: u64) -> Self {
        TraceConfig {
            horizon: DAY,
            mean_rate,
            tidal_ratio: 6.0,
            peak_hour: 13.0,
            period: DAY,
            burst_mult: 3.0,
            burst_mean: 30.0,
            calm_mean: 600.0,
            seed,
        }
    }

    /// Same shape compressed to `horizon` seconds (fast evaluation runs;
    /// the tide still completes exactly one day-cycle).
    pub fn compressed(horizon: f64, mean_rate: f64, seed: u64) -> Self {
        TraceConfig {
            horizon,
            period: horizon,
            burst_mean: (30.0 * horizon / DAY).max(2.0),
            calm_mean: (600.0 * horizon / DAY).max(20.0),
            ..Self::paper_24h(mean_rate, seed)
        }
    }

    /// Tidal base rate at time t (req/s), before burst modulation.
    /// Shaped so mean over a period = mean_rate and max/min = tidal_ratio.
    pub fn tidal_rate(&self, t: f64) -> f64 {
        let ratio = self.tidal_ratio.max(1.0);
        // rate = m * (1 + a*cos(phase)) with a = (ratio-1)/(ratio+1)
        let a = (ratio - 1.0) / (ratio + 1.0);
        let peak_t = self.peak_hour / 24.0 * self.period;
        let phase = (t - peak_t) / self.period * std::f64::consts::TAU;
        self.mean_rate * (1.0 + a * phase.cos())
    }
}

/// A generated trace: arrival offsets (sorted, seconds from start) plus the
/// burst-state intervals for inspection.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub arrivals: Vec<f64>,
    /// [start, end) intervals spent in the burst state.
    pub burst_intervals: Vec<(f64, f64)>,
}

impl Trace {
    pub fn generate(cfg: &TraceConfig) -> Trace {
        let mut rng = Rng::new(cfg.seed);
        // 1. Burst-state schedule (alternating exponential sojourns).
        let mut bursts = Vec::new();
        let mut t = 0.0;
        let mut in_burst = false;
        // Randomize the initial phase.
        if rng.bool(cfg.burst_mean / (cfg.burst_mean + cfg.calm_mean)) {
            in_burst = true;
        }
        let mut burst_start = 0.0;
        while t < cfg.horizon {
            let sojourn = if in_burst {
                rng.exponential(1.0 / cfg.burst_mean)
            } else {
                rng.exponential(1.0 / cfg.calm_mean)
            };
            t += sojourn;
            if in_burst {
                bursts.push((burst_start, t.min(cfg.horizon)));
            } else {
                burst_start = t;
            }
            in_burst = !in_burst;
        }

        // 2. Lewis thinning against the max possible rate.
        let lambda_max = cfg.mean_rate
            * (1.0 + (cfg.tidal_ratio - 1.0) / (cfg.tidal_ratio + 1.0))
            * cfg.burst_mult.max(1.0);
        let mut arrivals = Vec::new();
        let mut t = 0.0;
        let in_burst_at = |t: f64, bursts: &[(f64, f64)]| {
            // bursts are sorted; binary search the interval
            match bursts.binary_search_by(|&(s, _)| s.total_cmp(&t)) {
                Ok(_) => true,
                Err(i) => i > 0 && t < bursts[i - 1].1,
            }
        };
        loop {
            t += rng.exponential(lambda_max);
            if t >= cfg.horizon {
                break;
            }
            let mut rate = cfg.tidal_rate(t);
            if in_burst_at(t, &bursts) {
                rate *= cfg.burst_mult;
            }
            if rng.f64() < rate / lambda_max {
                arrivals.push(t);
            }
        }
        Trace {
            arrivals,
            burst_intervals: bursts,
        }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Scale timestamps by `factor` (paper §7.1: scale the real-world trace
    /// so arrivals match testbed capacity while keeping the distribution
    /// shape). factor > 1 stretches (lower rate).
    pub fn scale_time(&self, factor: f64) -> Trace {
        Trace {
            arrivals: self.arrivals.iter().map(|&t| t * factor).collect(),
            burst_intervals: self
                .burst_intervals
                .iter()
                .map(|&(a, b)| (a * factor, b * factor))
                .collect(),
        }
    }

    /// Overlay a flash crowd (PR 9's burst regime): extra Poisson arrivals
    /// at `(mult - 1) × tidal_rate(t)` inside `[at, at + dur)`, so the
    /// local rate becomes `mult ×` the base tide — the paper's short-scale
    /// burstiness pushed to regime scale, the scenario the SLO guard's
    /// brownout ladder exists for. Deterministic in `seed`; existing
    /// arrivals are untouched and the result stays sorted. The crowd
    /// window is recorded as a burst interval for inspection.
    pub fn with_flash_crowd(
        &self,
        cfg: &TraceConfig,
        at: f64,
        dur: f64,
        mult: f64,
        seed: u64,
    ) -> Trace {
        let end = (at + dur).min(cfg.horizon);
        let ratio = cfg.tidal_ratio.max(1.0);
        let extra_peak =
            cfg.mean_rate * (1.0 + (ratio - 1.0) / (ratio + 1.0)) * (mult - 1.0).max(0.0);
        let mut rng = Rng::new(seed);
        let mut arrivals = self.arrivals.clone();
        if extra_peak > 0.0 && end > at {
            // Lewis thinning against the crowd's peak extra rate.
            let mut t = at;
            loop {
                t += rng.exponential(extra_peak);
                if t >= end {
                    break;
                }
                let rate = cfg.tidal_rate(t) * (mult - 1.0);
                if rng.f64() < rate / extra_peak {
                    arrivals.push(t);
                }
            }
        }
        arrivals.sort_by(f64::total_cmp);
        let mut bursts = self.burst_intervals.clone();
        bursts.push((at, end));
        bursts.sort_by(|x, y| x.0.total_cmp(&y.0));
        Trace {
            arrivals,
            burst_intervals: bursts,
        }
    }

    /// Re-modulate this trace with a second diurnal envelope (e.g. a
    /// weekly cycle over a daily tide): each arrival is kept with
    /// probability `(1 + amp·cos(2πt/period)) / (1 + amp)` — deterministic
    /// thinning, so the result is a subset of the original arrivals and
    /// stays sorted. `amp` is clamped to [0, 1]; 0 keeps everything.
    pub fn with_diurnal_overlay(&self, amp: f64, period: f64, seed: u64) -> Trace {
        let a = amp.clamp(0.0, 1.0);
        let mut rng = Rng::new(seed);
        let arrivals = self
            .arrivals
            .iter()
            .copied()
            .filter(|&t| {
                let keep = (1.0 + a * (t / period * std::f64::consts::TAU).cos()) / (1.0 + a);
                rng.f64() < keep
            })
            .collect();
        Trace {
            arrivals,
            burst_intervals: self.burst_intervals.clone(),
        }
    }

    /// Requests per bin (Fig. 2's plotted series).
    pub fn rate_series(&self, horizon: f64, bins: usize) -> Vec<f64> {
        let mut counts = vec![0.0; bins];
        let w = horizon / bins as f64;
        for &t in &self.arrivals {
            if t < horizon {
                counts[((t / w) as usize).min(bins - 1)] += 1.0;
            }
        }
        counts.iter().map(|c| c / w).collect()
    }

    // ---- persistence (JSON lines of arrival offsets) --------------------

    pub fn to_json(&self) -> Json {
        Json::obj().set(
            "arrivals",
            Json::Arr(self.arrivals.iter().map(|&t| Json::Num(t)).collect()),
        )
    }

    pub fn from_json(j: &Json) -> Option<Trace> {
        let arrivals = j
            .get("arrivals")?
            .as_arr()?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        Some(Trace {
            arrivals,
            burst_intervals: Vec::new(),
        })
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    pub fn load(path: &str) -> anyhow::Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Trace::from_json(&j).ok_or_else(|| anyhow::anyhow!("bad trace file"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rate_close_to_config() {
        let cfg = TraceConfig {
            burst_mult: 1.0, // isolate the tide
            ..TraceConfig::paper_24h(0.5, 1)
        };
        let tr = Trace::generate(&cfg);
        let measured = tr.len() as f64 / cfg.horizon;
        assert!(
            (measured - 0.5).abs() < 0.05,
            "measured {measured} vs 0.5"
        );
    }

    #[test]
    fn tidal_ratio_visible() {
        let cfg = TraceConfig {
            burst_mult: 1.0,
            ..TraceConfig::paper_24h(1.0, 2)
        };
        let tr = Trace::generate(&cfg);
        let series = tr.rate_series(DAY, 24); // hourly bins
        let peak = series.iter().cloned().fold(0.0, f64::max);
        let trough = series.iter().cloned().fold(f64::INFINITY, f64::min);
        let ratio = peak / trough.max(1e-9);
        assert!(ratio > 3.0 && ratio < 12.0, "ratio {ratio}");
        // Peak bin near 13:00.
        let peak_bin = series
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!((10..=16).contains(&peak_bin), "peak at hour {peak_bin}");
    }

    #[test]
    fn bursts_raise_local_rate() {
        let cfg = TraceConfig {
            tidal_ratio: 1.0, // isolate bursts
            burst_mult: 5.0,
            burst_mean: 50.0,
            calm_mean: 50.0,
            ..TraceConfig::paper_24h(1.0, 3)
        };
        let tr = Trace::generate(&cfg);
        // Rate inside burst intervals should exceed outside.
        let mut in_b = 0.0;
        let mut in_t = 0.0;
        for &(s, e) in &tr.burst_intervals {
            in_t += e - s;
            in_b += tr.arrivals.iter().filter(|&&t| t >= s && t < e).count() as f64;
        }
        let out_t = cfg.horizon - in_t;
        let out_b = tr.len() as f64 - in_b;
        assert!(in_t > 0.0 && out_t > 0.0);
        let ratio = (in_b / in_t) / (out_b / out_t);
        assert!(ratio > 2.5, "burst rate ratio {ratio}");
    }

    #[test]
    fn deterministic_and_sorted() {
        let cfg = TraceConfig::compressed(1000.0, 2.0, 7);
        let a = Trace::generate(&cfg);
        let b = Trace::generate(&cfg);
        assert_eq!(a.arrivals, b.arrivals);
        assert!(a.arrivals.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn scaling_preserves_count() {
        let cfg = TraceConfig::compressed(500.0, 1.0, 9);
        let tr = Trace::generate(&cfg);
        let scaled = tr.scale_time(2.0);
        assert_eq!(tr.len(), scaled.len());
        assert!((scaled.arrivals[0] - tr.arrivals[0] * 2.0).abs() < 1e-12);
    }

    #[test]
    fn flash_crowd_raises_rate_only_inside_the_window() {
        let cfg = TraceConfig::compressed(600.0, 2.0, 13);
        let base = Trace::generate(&cfg);
        let crowd = base.with_flash_crowd(&cfg, 200.0, 60.0, 4.0, 99);
        assert!(crowd.len() > base.len(), "the crowd must add arrivals");
        assert!(crowd.arrivals.windows(2).all(|w| w[0] <= w[1]), "sorted");
        // Every added arrival falls inside the crowd window.
        let outside_base = base
            .arrivals
            .iter()
            .filter(|&&t| !(200.0..260.0).contains(&t))
            .count();
        let outside_crowd = crowd
            .arrivals
            .iter()
            .filter(|&&t| !(200.0..260.0).contains(&t))
            .count();
        assert_eq!(outside_base, outside_crowd, "arrivals outside untouched");
        // Rate inside the window roughly mult× the base's.
        let in_base = base.len() - outside_base;
        let in_crowd = crowd.len() - outside_crowd;
        assert!(
            in_crowd as f64 > 2.0 * in_base.max(1) as f64,
            "crowd window must be much denser: {in_crowd} vs {in_base}"
        );
        // Deterministic.
        let again = base.with_flash_crowd(&cfg, 200.0, 60.0, 4.0, 99);
        assert_eq!(crowd.arrivals, again.arrivals);
    }

    #[test]
    fn diurnal_overlay_thins_deterministically() {
        let cfg = TraceConfig::compressed(600.0, 2.0, 13);
        let base = Trace::generate(&cfg);
        let wk = base.with_diurnal_overlay(0.8, 600.0, 5);
        assert!(wk.len() < base.len(), "amp 0.8 must thin the trace");
        assert!(wk.len() > 0);
        assert!(wk.arrivals.windows(2).all(|w| w[0] <= w[1]), "sorted");
        // Subset property: every kept arrival came from the base.
        let mut it = base.arrivals.iter();
        assert!(
            wk.arrivals.iter().all(|t| it.any(|b| b == t)),
            "overlay output must be a subset of the input"
        );
        assert_eq!(
            wk.arrivals,
            base.with_diurnal_overlay(0.8, 600.0, 5).arrivals
        );
        // amp 0 keeps everything.
        assert_eq!(base.with_diurnal_overlay(0.0, 600.0, 5).len(), base.len());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = TraceConfig::compressed(200.0, 1.0, 11);
        let tr = Trace::generate(&cfg);
        let j = tr.to_json();
        let tr2 = Trace::from_json(&j).unwrap();
        assert_eq!(tr.arrivals.len(), tr2.arrivals.len());
    }
}

//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§7) on the simulated A100/LLaMA-8B substrate. Each `figN`
//! function runs the workloads, prints the paper-shaped table/plot, and
//! returns the raw data as JSON (benches tee it into bench_output).
//!
//! Paper ↔ harness map (see DESIGN.md §4 for the full index):
//!   Table 1 — dataset prefix-sharing structure        -> `table1`
//!   Fig. 2  — 24 h tidal + bursty online trace        -> `fig2`
//!   Fig. 6  — offline throughput speedup by strategy  -> `fig6`
//!   Fig. 7  — online TTFT/TPOT distributions          -> `fig7`
//!   Fig. 8  — active online vs offline over the trace -> `fig8`
//!   Fig. 9  — prefix-cache hit ratio over time        -> `fig9`
//!   Fig. 10 — memory occupancy breakdown              -> `fig10`
//!   Fig. 11 — predicted vs actual online demand       -> `fig11`

use crate::config::{SchedulerKind, SystemConfig};
use crate::core::{PromptSpec, RequestStore, TaskClass};
use crate::engine::{sim::SimBackend, Engine};
use crate::estimator::TimeModel;
use crate::kvcache::CacheStats;
use crate::metrics::{windowed_ratio, Metrics};
use crate::serve::{EngineServe, NullSink, Serve, SubmitSpec};
use crate::trace::{Trace, TraceConfig};
use crate::utils::ascii;
use crate::utils::json::Json;
use crate::utils::rng::Rng;
use crate::utils::stats::Summary;
use crate::workload::{synthesize, table1_specs, DatasetSpec};

/// Experiment scale knobs. `quick` shrinks horizons for CI-speed runs.
#[derive(Clone, Debug)]
pub struct FigureOpts {
    /// Sim horizon in seconds (the "24 h" trace is compressed onto this).
    pub horizon: f64,
    /// Mean online arrival rate over the tide (req/s).
    pub mean_rate: f64,
    pub seed: u64,
}

impl FigureOpts {
    /// Default scale. mean_rate 12 req/s reproduces the paper's regime:
    /// the instance is provisioned for the online *peak* (~20 req/s after
    /// the 1.71x tidal amplitude), so online KV pressure is high enough
    /// that LRU flushes shared offline prefixes during bursts — the effect
    /// Echo's cache manager exists to prevent.
    pub fn standard() -> Self {
        FigureOpts {
            horizon: 480.0,
            mean_rate: 12.0,
            seed: 42,
        }
    }

    pub fn quick() -> Self {
        FigureOpts {
            horizon: 180.0,
            mean_rate: 12.0,
            seed: 42,
        }
    }
}

/// One strategy × dataset run outcome.
pub struct RunResult {
    pub kind: SchedulerKind,
    pub metrics: Metrics,
    pub cache: CacheStats,
    pub predictor_history: Vec<(f64, f64, f64)>,
    pub clock: f64,
}

/// Offline backlog sized so it outlasts the horizon for every dataset,
/// even when prefix caching accelerates requests ~10x (§7.2 submits the
/// whole backlog up front; a drained pool would cap measured throughput).
/// Shared with the `simulate`/`cluster` CLI auto-sizing.
pub fn backlog_size(spec: &DatasetSpec, horizon: f64) -> usize {
    let per_req = (spec.mean_prompt as f64 / 9_500.0).max(0.02);
    let cache_boost = if spec.shared_frac > 0.5 { 10.0 } else { 1.5 };
    ((horizon / per_req) * cache_boost) as usize + 64
}

/// Shared mixed-workload runner behind Figures 6-11.
pub fn run_mixed(
    kind: SchedulerKind,
    offline_spec: &DatasetSpec,
    opts: &FigureOpts,
) -> anyhow::Result<RunResult> {
    let mut cfg = SystemConfig::a100_llama8b();
    cfg.scheduler.kind = kind;
    cfg.seed = opts.seed;
    // Compress the predictor to the compressed trace's time scale.
    cfg.predictor.history_horizon = opts.horizon / 24.0;
    cfg.predictor.update_period = opts.horizon / 24.0 / 6.0;

    let backend = SimBackend::new(TimeModel::new(cfg.time_model), opts.seed ^ 0x5a5a, 0.02);
    let mut front = EngineServe::new(Engine::new(cfg, backend));
    front.engine.set_sample_interval(opts.horizon / 480.0);

    // Online load: compressed paper-shaped trace + ShareGPT-like prompts
    // (§7.1: online tasks simulated with the real-world trace + ShareGPT).
    let trace = Trace::generate(&TraceConfig::compressed(
        opts.horizon,
        opts.mean_rate,
        opts.seed,
    ));
    let online_spec = DatasetSpec::sharegpt();
    let mut rng = Rng::new(opts.seed ^ 0x00ff);
    for &t in &trace.arrivals {
        let (prompt, out) = draw_request(&online_spec, &mut rng);
        front.submit(SubmitSpec::online(prompt, out).at(t))?;
    }

    // Offline backlog, submitted all at once at t = 0 (§7.2). Submission
    // order interleaves prefix groups (batch-API jobs from many users — the
    // paper's §4.1 R2/R5 example shows exactly this: same-prefix requests
    // are NOT adjacent in FCFS order; locality must be *recovered*).
    let n_off = backlog_size(offline_spec, opts.horizon);
    let mut scratch = RequestStore::new();
    let mut batch = synthesize(
        offline_spec,
        n_off,
        TaskClass::Offline,
        0.0,
        &mut scratch,
        &mut rng,
    );
    rng.shuffle(&mut batch.ids);
    for &id in &batch.ids {
        let r = scratch.get(id);
        front.submit(SubmitSpec::offline(r.prompt.clone(), r.max_new_tokens))?;
    }

    front.run_until(opts.horizon, &mut NullSink)?;
    let e = front.into_engine();
    Ok(RunResult {
        kind,
        cache: e.kv.stats.clone(),
        predictor_history: e.predictor.history.clone(),
        clock: e.clock,
        metrics: e.metrics,
    })
}

fn draw_request(spec: &DatasetSpec, rng: &mut Rng) -> (PromptSpec, usize) {
    let mu = (spec.mean_prompt as f64).ln() - spec.prompt_sigma * spec.prompt_sigma / 2.0;
    let len =
        (rng.lognormal(mu, spec.prompt_sigma).round() as usize).clamp(2, spec.mean_prompt * 8);
    let mu_o = (spec.mean_out as f64).ln() - spec.out_sigma * spec.out_sigma / 2.0;
    let out = (rng.lognormal(mu_o, spec.out_sigma).round() as usize).clamp(2, spec.mean_out * 8);
    (PromptSpec::sim(len, None), out)
}

// ---------------------------------------------------------------- Table 1

pub fn table1(seed: u64) -> (String, Json) {
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for spec in table1_specs() {
        let mut store = crate::core::RequestStore::new();
        let mut rng = Rng::new(seed);
        let n = if spec.mean_prompt > 10_000 { 1_000 } else { 2_000 };
        let b = synthesize(&spec, n, TaskClass::Offline, 0.0, &mut store, &mut rng);
        let mean_prompt =
            store.iter().map(|r| r.prompt.total_len as f64).sum::<f64>() / store.len() as f64;
        rows.push(vec![
            spec.name.to_string(),
            format!("{mean_prompt:.0}"),
            format!("{:.1}%", b.shared_rate() * 100.0),
        ]);
        jrows.push(
            Json::obj()
                .set("dataset", spec.name)
                .set("mean_prompt", mean_prompt)
                .set("shared_rate", b.shared_rate()),
        );
    }
    let text = ascii::table(
        "Table 1: prefix sharing rate of synthesized workloads \
         (paper: 308/<5%, 23474/91%, 1835/85%, 9865/88%)",
        &["Workload", "Avg. Prompt", "Shared Rate"],
        &rows,
    );
    (text, Json::obj().set("rows", Json::Arr(jrows)))
}

// ----------------------------------------------------------------- Fig. 2

pub fn fig2(opts: &FigureOpts) -> (String, Json) {
    let cfg = TraceConfig::paper_24h(opts.mean_rate, opts.seed);
    let tr = Trace::generate(&cfg);
    let bins = 96; // 15-minute bins like the paper's plot
    let series = tr.rate_series(cfg.horizon, bins);
    let peak = series.iter().cloned().fold(0.0, f64::max);
    let trough = series.iter().cloned().fold(f64::INFINITY, f64::min);
    let text = ascii::line_plot(
        &format!(
            "Fig. 2: 24-hour online trace (peak/trough = {:.1}x, paper ~6x)",
            peak / trough.max(1e-9)
        ),
        &[("req/s", &series)],
        12,
        "req/s",
    );
    let j = Json::obj()
        .set("bins_15min", series.clone())
        .set("peak_trough_ratio", peak / trough.max(1e-9))
        .set("arrivals", tr.len());
    (text, j)
}

// ----------------------------------------------------------------- Fig. 6

pub fn fig6_datasets() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec::sharegpt(),
        DatasetSpec::loogle_qa_short(),
        DatasetSpec::loogle_qa_long(),
    ]
}

pub fn fig6(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let mut text = String::new();
    let mut jd = Vec::new();
    for spec in fig6_datasets() {
        let mut rows = Vec::new();
        let mut base = None;
        let mut jrows = Vec::new();
        for kind in SchedulerKind::all() {
            let r = run_mixed(kind, &spec, opts)?;
            let thr = r.metrics.offline_throughput();
            let base_thr = *base.get_or_insert(thr);
            let (a_ttft, a_tok) = r.metrics.slo_attainment(&crate::core::Slo::paper_eval());
            rows.push((
                format!("{}", kind.name()),
                if base_thr > 0.0 { thr / base_thr } else { 0.0 },
            ));
            jrows.push(
                Json::obj()
                    .set("strategy", kind.name())
                    .set("offline_throughput_tok_s", thr)
                    .set("speedup_vs_bs", if base_thr > 0.0 { thr / base_thr } else { 0.0 })
                    .set("ttft_attainment", a_ttft)
                    .set("token_attainment", a_tok)
                    .set("hit_ratio", r.cache.hit_ratio())
                    .set("preemptions", r.metrics.preemptions),
            );
        }
        text.push_str(&ascii::bar_chart(
            &format!(
                "Fig. 6: offline throughput speedup vs BS — offline = {} \
                 (paper: Echo up to 3.3x)",
                spec.name
            ),
            &rows,
            "x",
        ));
        jd.push(Json::obj().set("dataset", spec.name).set("rows", Json::Arr(jrows)));
    }
    Ok((text, Json::obj().set("datasets", Json::Arr(jd))))
}

// ----------------------------------------------------------------- Fig. 7

pub fn fig7(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let spec = DatasetSpec::loogle_qa_short();
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for kind in SchedulerKind::all() {
        let r = run_mixed(kind, &spec, opts)?;
        let ttft = Summary::of(&r.metrics.online_ttft);
        let tpot = Summary::of(&r.metrics.online_tpot);
        let (a_ttft, a_tok) = r.metrics.slo_attainment(&crate::core::Slo::paper_eval());
        rows.push(vec![
            kind.name().to_string(),
            format!("{:.3}", ttft.p50),
            format!("{:.3}", ttft.p90),
            format!("{:.3}", ttft.p99),
            format!("{:.4}", tpot.p50),
            format!("{:.4}", tpot.p90),
            format!("{:.4}", tpot.p99),
            format!("{:.1}%", a_ttft * 100.0),
            format!("{:.1}%", a_tok * 100.0),
        ]);
        jrows.push(
            Json::obj()
                .set("strategy", kind.name())
                .set("ttft_p50", ttft.p50)
                .set("ttft_p90", ttft.p90)
                .set("ttft_p99", ttft.p99)
                .set("tpot_p50", tpot.p50)
                .set("tpot_p90", tpot.p90)
                .set("tpot_p99", tpot.p99)
                .set("ttft_attainment", a_ttft)
                .set("token_attainment", a_tok),
        );
    }
    let text = ascii::table(
        "Fig. 7: online TTFT/TPOT distributions (paper: all SLO-aware \
         strategies meet the 90% attainment bar; BS has the lowest TTFT)",
        &[
            "Strategy", "TTFT p50", "p90", "p99", "TPOT p50", "p90", "p99",
            "TTFT att.", "token att.",
        ],
        &rows,
    );
    Ok((text, Json::obj().set("rows", Json::Arr(jrows))))
}

// ----------------------------------------------------------------- Fig. 8

pub fn fig8(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let r = run_mixed(SchedulerKind::Echo, &DatasetSpec::loogle_qa_short(), opts)?;
    let bins = 120;
    let on = r.metrics.active_online.binned(0.0, opts.horizon, bins);
    let off = r.metrics.active_offline.binned(0.0, opts.horizon, bins);
    let text = ascii::line_plot(
        "Fig. 8: active online vs offline requests over the trace \
         (paper: anti-correlated; offline fills online troughs)",
        &[("online", &on), ("offline", &off)],
        12,
        "active requests",
    );
    // Anti-correlation statistic for EXPERIMENTS.md.
    let corr = pearson(&on, &off);
    let j = Json::obj()
        .set("active_online", on.clone())
        .set("active_offline", off.clone())
        .set("pearson_corr", corr);
    Ok((text, j))
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    if n < 2 {
        return 0.0;
    }
    let ma = a.iter().take(n).sum::<f64>() / n as f64;
    let mb = b.iter().take(n).sum::<f64>() / n as f64;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..n {
        cov += (a[i] - ma) * (b[i] - mb);
        va += (a[i] - ma) * (a[i] - ma);
        vb += (b[i] - mb) * (b[i] - mb);
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-12)
}

// ----------------------------------------------------------------- Fig. 9

pub fn fig9(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let spec = DatasetSpec::loogle_qa_short();
    // "Naive2" in the paper = KV-aware scheduler with vanilla LRU cache
    // (our BS+E+S); Echo adds the task-aware manager.
    let naive = run_mixed(SchedulerKind::BsES, &spec, opts)?;
    let echo = run_mixed(SchedulerKind::Echo, &spec, opts)?;
    let bins = 120;
    let series_of = |r: &RunResult| {
        windowed_ratio(&r.metrics.cache_lookups_cum, &r.metrics.cache_hits_cum)
            .binned(0.0, opts.horizon, bins)
    };
    let s_naive = series_of(&naive);
    let s_echo = series_of(&echo);
    let text = ascii::line_plot(
        &format!(
            "Fig. 9: prefix-cache hit ratio over time — Echo overall {:.1}% \
             (paper: 78.6% LooGLE QA_Short), Naive2 {:.1}%",
            echo.cache.hit_ratio() * 100.0,
            naive.cache.hit_ratio() * 100.0
        ),
        &[("echo", &s_echo), ("naive2", &s_naive)],
        12,
        "hit ratio",
    );
    let j = Json::obj()
        .set("echo_overall", echo.cache.hit_ratio())
        .set("naive2_overall", naive.cache.hit_ratio())
        .set("echo_series", s_echo.clone())
        .set("naive2_series", s_naive.clone());
    Ok((text, j))
}

// ---------------------------------------------------------------- Fig. 10

pub fn fig10(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let r = run_mixed(SchedulerKind::Echo, &DatasetSpec::loogle_qa_short(), opts)?;
    let bins = 120;
    let cap = SystemConfig::a100_llama8b().cache.capacity_tokens as f64;
    let norm = |xs: Vec<f64>| xs.into_iter().map(|x| x / cap).collect::<Vec<f64>>();
    let running = norm(r.metrics.mem_running.binned(0.0, opts.horizon, bins));
    let c_on = norm(r.metrics.mem_cached_online.binned(0.0, opts.horizon, bins));
    let c_off = norm(r.metrics.mem_cached_offline.binned(0.0, opts.horizon, bins));
    let free = norm(r.metrics.mem_free.binned(0.0, opts.horizon, bins));
    let occupied_mean = running.iter().sum::<f64>() / running.len() as f64;
    let text = ascii::line_plot(
        &format!(
            "Fig. 10: memory occupancy fractions (running mean {:.0}%; \
             paper: >50% occupied most iterations)",
            occupied_mean * 100.0
        ),
        &[
            ("running", &running),
            ("online-free", &c_on),
            ("offline-free", &c_off),
            ("unused", &free),
        ],
        12,
        "fraction of KV capacity",
    );
    let j = Json::obj()
        .set("running", running.clone())
        .set("cached_online", c_on.clone())
        .set("cached_offline", c_off.clone())
        .set("free", free.clone())
        .set("running_mean_frac", occupied_mean);
    Ok((text, j))
}

// ---------------------------------------------------------------- Fig. 11

pub fn fig11(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let r = run_mixed(SchedulerKind::Echo, &DatasetSpec::loogle_qa_short(), opts)?;
    let predicted: Vec<f64> = r.predictor_history.iter().map(|&(_, p, _)| p).collect();
    let actual: Vec<f64> = r.predictor_history.iter().map(|&(_, _, a)| a).collect();
    let covered = predicted
        .iter()
        .zip(&actual)
        .filter(|(p, a)| a <= p)
        .count() as f64
        / predicted.len().max(1) as f64;
    let text = ascii::line_plot(
        &format!(
            "Fig. 11: predicted (mu+2sigma) vs actual online KV demand \
             (coverage {:.0}%, paper targets ~95%)",
            covered * 100.0
        ),
        &[("predicted", &predicted), ("actual", &actual)],
        12,
        "KV tokens",
    );
    let j = Json::obj()
        .set("predicted", predicted.clone())
        .set("actual", actual.clone())
        .set("coverage", covered);
    Ok((text, j))
}

// ------------------------------------------------------------- Ablations

/// Design-choice ablations beyond the paper's figures (DESIGN.md §4):
/// threshold on/off and eviction-policy matrix on the Fig. 9 workload.
pub fn ablation_cache(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let spec = DatasetSpec::loogle_qa_short();
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for (name, task_aware, threshold) in [
        ("LRU, no threshold", false, false),
        ("LRU + threshold", false, true),
        ("priority, no threshold", true, false),
        ("priority + threshold (Echo)", true, true),
    ] {
        let mut cfg = SystemConfig::a100_llama8b();
        cfg.scheduler.kind = SchedulerKind::Echo;
        cfg.cache.task_aware = task_aware;
        cfg.cache.threshold = threshold;
        cfg.predictor.history_horizon = opts.horizon / 24.0;
        cfg.predictor.update_period = opts.horizon / 144.0;
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), opts.seed, 0.02);
        let mut front = EngineServe::new(Engine::new(cfg, backend));
        let trace = Trace::generate(&TraceConfig::compressed(
            opts.horizon,
            opts.mean_rate,
            opts.seed,
        ));
        let mut rng = Rng::new(opts.seed);
        for &t in &trace.arrivals {
            let (prompt, out) = draw_request(&DatasetSpec::sharegpt(), &mut rng);
            front.submit(SubmitSpec::online(prompt, out).at(t))?;
        }
        let n_off = backlog_size(&spec, opts.horizon);
        let mut scratch = RequestStore::new();
        let batch = synthesize(&spec, n_off, TaskClass::Offline, 0.0, &mut scratch, &mut rng);
        for &id in &batch.ids {
            let r = scratch.get(id);
            front.submit(SubmitSpec::offline(r.prompt.clone(), r.max_new_tokens))?;
        }
        front.run_until(opts.horizon, &mut NullSink)?;
        let e = front.into_engine();
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", e.metrics.offline_throughput()),
            format!("{:.1}%", e.kv.stats.hit_ratio() * 100.0),
            format!("{}", e.kv.stats.useful_evictions),
            format!("{}", e.metrics.preemptions),
        ]);
        jrows.push(
            Json::obj()
                .set("variant", name)
                .set("offline_throughput", e.metrics.offline_throughput())
                .set("hit_ratio", e.kv.stats.hit_ratio())
                .set("useful_evictions", e.kv.stats.useful_evictions)
                .set("preemptions", e.metrics.preemptions),
        );
    }
    let text = ascii::table(
        "Ablation: cache-manager components (Fig. 5's threshold made quantitative)",
        &["Variant", "off. thr (tok/s)", "hit ratio", "useful evictions", "preemptions"],
        &rows,
    );
    Ok((text, Json::obj().set("rows", Json::Arr(jrows))))
}

/// Mutation-budget sweep: the cost/benefit of the plan generator's
/// last-batch search reduction (§4.1).
pub fn ablation_budget(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    let spec = DatasetSpec::loogle_qa_short();
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for budget in [1usize, 4, 16, 64, 256] {
        let mut o = opts.clone();
        o.horizon = opts.horizon.min(300.0);
        let mut cfg = SystemConfig::a100_llama8b();
        cfg.scheduler.kind = SchedulerKind::Echo;
        cfg.scheduler.mutation_budget = budget;
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), o.seed, 0.02);
        let mut front = EngineServe::new(Engine::new(cfg, backend));
        let trace = Trace::generate(&TraceConfig::compressed(o.horizon, o.mean_rate, o.seed));
        let mut rng = Rng::new(o.seed);
        for &t in &trace.arrivals {
            let (prompt, out) = draw_request(&DatasetSpec::sharegpt(), &mut rng);
            front.submit(SubmitSpec::online(prompt, out).at(t))?;
        }
        let n_off = backlog_size(&spec, o.horizon);
        let mut scratch = RequestStore::new();
        let batch = synthesize(&spec, n_off, TaskClass::Offline, 0.0, &mut scratch, &mut rng);
        for &id in &batch.ids {
            let r = scratch.get(id);
            front.submit(SubmitSpec::offline(r.prompt.clone(), r.max_new_tokens))?;
        }
        // lint: allow-wall-clock(measures host wall time of the run itself; never feeds sim state)
        let wall = std::time::Instant::now();
        front.run_until(o.horizon, &mut NullSink)?;
        let wall = wall.elapsed().as_secs_f64();
        let e = front.into_engine();
        rows.push(vec![
            budget.to_string(),
            format!("{:.1}", e.metrics.offline_throughput()),
            format!("{:.1}%", e.kv.stats.hit_ratio() * 100.0),
            format!("{:.1}us", wall / e.metrics.iterations.max(1) as f64 * 1e6),
        ]);
        jrows.push(
            Json::obj()
                .set("budget", budget)
                .set("offline_throughput", e.metrics.offline_throughput())
                .set("hit_ratio", e.kv.stats.hit_ratio())
                .set("wall_us_per_iter", wall / e.metrics.iterations.max(1) as f64 * 1e6),
        );
    }
    let text = ascii::table(
        "Ablation: plan-generator mutation budget (search cost vs quality)",
        &["Budget", "off. thr (tok/s)", "hit ratio", "sched wall/iter"],
        &rows,
    );
    Ok((text, Json::obj().set("rows", Json::Arr(jrows))))
}

// ------------------------------------------------------- Cluster scaling

/// Cluster co-serving figure (beyond the paper, toward the ROADMAP's
/// production scale): the same tidal trace replayed against fleets of 1, 2,
/// and 4 replicas plus one tidally-autoscaled fleet. Reports per-fleet SLO
/// attainment, delivered offline throughput, cluster cache-hit rate, and
/// the autoscaler's replica-count timeline against the arrival tide.
pub fn fig_cluster(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    use crate::cluster::{
        offline_jobs, online_jobs_from_trace, online_session_spec, ClusterConfig, ScalePolicy,
    };
    use crate::serve::ClusterServe;
    let spec = DatasetSpec::loogle_qa_short();
    let trace = Trace::generate(&TraceConfig::compressed(
        opts.horizon,
        opts.mean_rate,
        opts.seed,
    ));
    // Session-prefix online mix: affinity routing needs shared prefixes.
    let online = online_jobs_from_trace(&trace, &online_session_spec(), opts.seed ^ 0x00ff);

    // `fleet_cap` = the largest replica count the run can reach; the
    // backlog must outlast the horizon even at that size, or throughput is
    // capped by starvation instead of capacity.
    // Fleets are driven through the serving front door: offline jobs and
    // the trace replay are ordinary `Serve` submissions.
    let run = |n: usize,
               fleet_cap: usize,
               scale: Option<ScalePolicy>|
     -> anyhow::Result<crate::cluster::ClusterReport> {
        let mut base = SystemConfig::a100_llama8b();
        base.seed = opts.seed;
        let mut cc = ClusterConfig::new(base, n);
        cc.scale = scale;
        let mut front = ClusterServe::new(cc);
        let n_jobs = backlog_size(&spec, opts.horizon) * fleet_cap;
        front.submit_offline_jobs(offline_jobs(&spec, n_jobs, opts.seed ^ 0x0ff0))?;
        front.submit_online_jobs(&online)?;
        front.run_until(opts.horizon, &mut NullSink)?;
        Ok(front.sim.report(opts.horizon))
    };

    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    let mut record = |label: String, r: &crate::cluster::ClusterReport| {
        rows.push(vec![
            label.clone(),
            format!("{:.1}%", r.online_attainment.0 * 100.0),
            format!("{:.1}%", r.online_attainment.1 * 100.0),
            format!("{:.0}", r.offline_throughput),
            format!("{:.1}%", r.cluster_hit_ratio * 100.0),
            format!("{:.1}%", {
                let d = r.router.dispatched_online.max(1);
                r.router.affinity_routed as f64 / d as f64 * 100.0
            }),
            format!("{:.2}", r.mean_replicas),
        ]);
        jrows.push(
            Json::obj()
                .set("fleet", label)
                .set("ttft_attainment", r.online_attainment.0)
                .set("token_attainment", r.online_attainment.1)
                .set("offline_throughput_tok_s", r.offline_throughput)
                .set("cluster_hit_ratio", r.cluster_hit_ratio)
                .set("affinity_routed", r.router.affinity_routed)
                .set("capacity_vetoes", r.router.capacity_vetoes)
                .set("mean_replicas", r.mean_replicas)
                .set("peak_replicas", r.peak_replicas),
        );
    };

    for n in [1usize, 2, 4] {
        let r = run(n, n, None)?;
        record(format!("fixed x{n}"), &r);
    }
    let auto_start = 1usize;
    let auto = run(auto_start, 4, Some(ScalePolicy::tidal(auto_start, 4)))?;
    record("autoscaled 1-4".to_string(), &auto);

    let mut text = ascii::table(
        "Cluster: tidal trace vs fleet size (prefix-affinity router + \
         offline work-stealing)",
        &[
            "Fleet", "TTFT att.", "token att.", "off. tok/s", "hit ratio",
            "affinity", "mean N",
        ],
        &rows,
    );

    // Autoscaler timeline vs the arrival tide.
    let bins = 96;
    let rate = trace.rate_series(opts.horizon, bins);
    let max_rate = rate.iter().cloned().fold(1e-9, f64::max);
    let rate_norm: Vec<f64> = rate.iter().map(|r| r / max_rate).collect();
    let mut fleet = vec![0.0; bins];
    let w = opts.horizon / bins as f64;
    let mut cur = auto_start as f64;
    let mut ti = 0usize;
    for (b, slot) in fleet.iter_mut().enumerate() {
        let t_bin = (b as f64 + 1.0) * w;
        while ti < auto.timeline.len() && auto.timeline[ti].0 <= t_bin {
            cur = auto.timeline[ti].1 as f64;
            ti += 1;
        }
        *slot = cur;
    }
    let peak = auto.peak_replicas.max(1) as f64;
    let fleet_norm: Vec<f64> = fleet.iter().map(|n| n / peak).collect();
    text.push_str(&ascii::line_plot(
        &format!(
            "Cluster autoscaling: replicas (peak {}) track the tide \
             (normalized)",
            auto.peak_replicas
        ),
        &[("arrival rate", &rate_norm), ("replicas", &fleet_norm)],
        10,
        "normalized",
    ));
    let j = Json::obj()
        .set("rows", Json::Arr(jrows))
        .set(
            "autoscale_timeline",
            Json::Arr(
                auto.timeline
                    .iter()
                    .map(|&(t, n)| Json::Arr(vec![Json::Num(t), Json::Num(n as f64)]))
                    .collect(),
            ),
        )
        .set("rate_bins", rate);
    Ok((text, j))
}

// ------------------------------------------------------- SLO guard (PR 9)

/// SLO-guard headline figure (PR 9): online attainment and delivered
/// offline throughput under a flash-crowd + diurnal-overlay trace for
/// three co-location policies on the same 2-replica fleet —
///
///   * **no guard**: uncapped harvesting; best offline throughput, online
///     latency unprotected through the crowd;
///   * **static reservation**: a fixed per-iteration offline token cap
///     sized for the crowd's peak, so it throttles offline *all day* to
///     survive one burst — the classic static-partitioning baseline;
///   * **SLO guard**: the measured-latency feedback controller (AIMD cap
///     + brownout ladder), which harvests at full rate through the calm
///     and sheds offline only while attainment actually degrades.
///
/// The headline reproduces the shape of Echo's claim (§7: up to 3.3× the
/// offline throughput of static partitioning at the same attainment bar):
/// `guard_vs_static_throughput` is that multiple on this substrate.
pub fn fig_slo_guard(opts: &FigureOpts) -> anyhow::Result<(String, Json)> {
    use crate::cluster::{
        offline_jobs, online_jobs_from_trace, online_session_spec, ClusterConfig,
    };
    use crate::serve::ClusterServe;
    use crate::slo::SloGuardConfig;
    let spec = DatasetSpec::loogle_qa_short();
    let tcfg = TraceConfig::compressed(opts.horizon, opts.mean_rate, opts.seed);
    // Tidal base + a 4x flash crowd across 15% of the day + a mild second
    // diurnal envelope: the burst regime the brownout ladder exists for.
    let trace = Trace::generate(&tcfg)
        .with_flash_crowd(
            &tcfg,
            opts.horizon * 0.4,
            opts.horizon * 0.15,
            4.0,
            opts.seed ^ 0xf1a5,
        )
        .with_diurnal_overlay(0.2, opts.horizon, opts.seed ^ 0xd1e1);
    let online = online_jobs_from_trace(&trace, &online_session_spec(), opts.seed ^ 0x00ff);

    // Reservation sized for the crowd peak: small enough to hold online
    // latency through the burst, which means throttling offline always.
    const STATIC_CAP: usize = 64;

    let run = |offline_cap: usize,
               guard: Option<SloGuardConfig>|
     -> anyhow::Result<crate::cluster::ClusterReport> {
        let mut base = SystemConfig::a100_llama8b();
        base.seed = opts.seed;
        let mut cc = ClusterConfig::new(base, 2);
        cc.offline_cap = offline_cap;
        cc.guard = guard;
        let mut front = ClusterServe::new(cc);
        let n_jobs = backlog_size(&spec, opts.horizon) * 2;
        front.submit_offline_jobs(offline_jobs(&spec, n_jobs, opts.seed ^ 0x0ff0))?;
        front.submit_online_jobs(&online)?;
        front.run_until(opts.horizon, &mut NullSink)?;
        Ok(front.sim.report(opts.horizon))
    };

    let unguarded = run(usize::MAX, None)?;
    let reserved = run(STATIC_CAP, None)?;
    let guarded = run(usize::MAX, Some(SloGuardConfig::default()))?;
    let target = SloGuardConfig::default().target;

    let ratio = |r: &crate::cluster::ClusterReport| {
        if reserved.offline_throughput > 0.0 {
            r.offline_throughput / reserved.offline_throughput
        } else {
            0.0
        }
    };
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for (label, r) in [
        ("no guard", &unguarded),
        ("static reservation", &reserved),
        ("SLO guard", &guarded),
    ] {
        rows.push(vec![
            label.to_string(),
            format!("{:.1}%", r.online_attainment.0 * 100.0),
            format!("{:.1}%", r.online_attainment.1 * 100.0),
            format!("{:.0}", r.offline_throughput),
            format!("{:.2}x", ratio(r)),
            format!("{}", r.guard.transitions),
            format!("{}", r.guard.shed_submits + r.guard.retry_submits),
        ]);
        jrows.push(
            Json::obj()
                .set("policy", label)
                .set("ttft_attainment", r.online_attainment.0)
                .set("token_attainment", r.online_attainment.1)
                .set("offline_throughput_tok_s", r.offline_throughput)
                .set("throughput_vs_static", ratio(r))
                .set("guard", r.guard.to_json()),
        );
    }
    let text = ascii::table(
        &format!(
            "SLO guard: flash-crowd co-location — guard delivers {:.2}x the \
             static reservation's offline throughput (paper headline: up to \
             3.3x) at attainment target {:.0}%",
            ratio(&guarded),
            target * 100.0
        ),
        &[
            "Policy", "TTFT att.", "token att.", "off. tok/s", "vs static",
            "transitions", "backpressured",
        ],
        &rows,
    );
    let j = Json::obj()
        .set("rows", Json::Arr(jrows))
        .set("guard_vs_static_throughput", ratio(&guarded))
        .set("unguarded_vs_static_throughput", ratio(&unguarded))
        .set("attainment_target", target)
        .set("crowd_window", Json::Arr(vec![
            Json::Num(opts.horizon * 0.4),
            Json::Num(opts.horizon * 0.55),
        ]));
    Ok((text, j))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> FigureOpts {
        FigureOpts {
            horizon: 60.0,
            mean_rate: 1.0,
            seed: 3,
        }
    }

    #[test]
    fn table1_has_four_rows() {
        let (_, j) = table1(1);
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn fig2_ratio_in_range() {
        let (_, j) = fig2(&FigureOpts { horizon: 600.0, mean_rate: 1.0, seed: 1 });
        let ratio = j.get("peak_trough_ratio").unwrap().as_f64().unwrap();
        assert!(ratio > 2.0, "ratio {ratio}");
    }

    #[test]
    fn run_mixed_completes() {
        let r = run_mixed(SchedulerKind::Echo, &DatasetSpec::sharegpt(), &tiny()).unwrap();
        assert!(r.metrics.iterations > 0);
        assert!(r.metrics.offline_tokens_out > 0);
    }

    #[test]
    fn fig_slo_guard_beats_static_reservation() {
        let opts = FigureOpts {
            horizon: 90.0,
            mean_rate: 2.0,
            seed: 7,
        };
        let (_, j) = fig_slo_guard(&opts).unwrap();
        let ratio = j
            .get("guard_vs_static_throughput")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(
            ratio > 1.0,
            "the guard must out-deliver the static reservation: {ratio}"
        );
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn fig6_speedup_shape() {
        // Even at tiny scale: Echo >= BS+E on the shared-prefix dataset.
        let opts = FigureOpts { horizon: 120.0, mean_rate: 1.2, seed: 5 };
        let spec = DatasetSpec::loogle_qa_short();
        let bse = run_mixed(SchedulerKind::BsE, &spec, &opts).unwrap();
        let echo = run_mixed(SchedulerKind::Echo, &spec, &opts).unwrap();
        assert!(
            echo.cache.hit_ratio() >= bse.cache.hit_ratio(),
            "echo hit {} vs bse {}",
            echo.cache.hit_ratio(),
            bse.cache.hit_ratio()
        );
    }
}

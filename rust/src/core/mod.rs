//! Core domain types: requests, task classes, prompts, SLOs.
//!
//! Everything the scheduler/KV-manager/estimator agree on lives here; the
//! modules themselves only exchange these types plus plain numbers.

pub mod request;
pub mod slo;
pub mod store;

pub use request::{Phase, PromptSpec, ReqState, Request, RequestId, TaskClass, Token};
pub use slo::Slo;
pub use store::RequestStore;

//! Request store: owns every request in the system by id.

use super::{ReqState, Request, RequestId};
use crate::utils::hash::FxHashMap;

#[derive(Default)]
pub struct RequestStore {
    map: FxHashMap<RequestId, Request>,
    next_id: RequestId,
}

impl RequestStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a request built elsewhere (workload generators assign ids via
    /// `fresh_id`).
    pub fn insert(&mut self, req: Request) {
        self.next_id = self.next_id.max(req.id + 1);
        self.map.insert(req.id, req);
    }

    pub fn fresh_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    pub fn get(&self, id: RequestId) -> &Request {
        &self.map[&id]
    }

    pub fn get_mut(&mut self, id: RequestId) -> &mut Request {
        // lint: allow-unwrap(indexing contract: callers pass live ids, like get())
        self.map.get_mut(&id).expect("unknown request id")
    }

    pub fn try_get(&self, id: RequestId) -> Option<&Request> {
        self.map.get(&id)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.map.values()
    }

    /// Ids currently in a given state (unordered).
    pub fn ids_in_state(&self, state: ReqState) -> Vec<RequestId> {
        self.map
            .values()
            .filter(|r| r.state == state)
            .map(|r| r.id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{PromptSpec, TaskClass};

    #[test]
    fn insert_get_fresh() {
        let mut s = RequestStore::new();
        let id = s.fresh_id();
        s.insert(Request::new(
            id,
            TaskClass::Online,
            0.0,
            PromptSpec::sim(10, None),
            5,
        ));
        assert_eq!(s.get(id).id, id);
        assert!(s.fresh_id() > id);
        assert_eq!(s.len(), 1);
    }
}

//! SLO definition (paper §2.2, §5.1): TTFT bounds time-to-first-token,
//! TPOT bounds the inter-token pace afterwards; per-token deadline is
//! `arrival + TTFT + i·TPOT`.

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slo {
    /// Time-to-first-token bound, seconds (paper eval: 1.0).
    pub ttft: f64,
    /// Time-per-output-token bound, seconds (paper eval: 0.05).
    pub tpot: f64,
}

impl Slo {
    pub fn new(ttft: f64, tpot: f64) -> Self {
        Slo { ttft, tpot }
    }

    /// The paper's evaluation setting (§7.2).
    pub fn paper_eval() -> Self {
        Slo {
            ttft: 1.0,
            tpot: 0.05,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants() {
        let s = Slo::paper_eval();
        assert_eq!(s.ttft, 1.0);
        assert_eq!(s.tpot, 0.05);
    }
}

//! Request lifecycle types shared by every Echo component.

use std::cell::{Cell, OnceCell};

/// Globally unique request id (monotonic per run).
pub type RequestId = u64;
/// Vocabulary token id (EchoLM vocab is small; u32 covers any real model).
pub type Token = u32;
/// Prefix-sharing group id (workload generator assigns these).
pub type GroupId = u64;

/// Online = interactive, SLO-bound; Offline = batched, throughput-oriented
/// (paper §2.2/§2.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskClass {
    Online,
    Offline,
}

impl TaskClass {
    pub fn is_online(self) -> bool {
        matches!(self, TaskClass::Online)
    }
}

/// Prompt content. The simulation path carries only *structure* (lengths +
/// prefix-group identity — all Echo's decisions depend on); the real-model
/// path additionally carries token ids.
#[derive(Clone, Debug)]
pub struct PromptSpec {
    pub total_len: usize,
    /// `(group, shared_len)`: the first `shared_len` tokens are identical
    /// across all requests of `group` (LooGLE-style shared article prefix).
    pub shared_prefix: Option<(GroupId, usize)>,
    /// Real token ids (PJRT backend only).
    pub tokens: Option<Vec<Token>>,
    /// Interned owner-independent leading content keys (see
    /// [`PromptSpec::affinity_keys`]); travels with clones, so a prompt
    /// hashed once by the cluster router is never re-hashed by the replica
    /// that receives it.
    shared_keys: OnceCell<Vec<u128>>,
    /// Block size the interned keys were computed with (consistency check).
    shared_keys_bs: Cell<usize>,
}

impl PromptSpec {
    pub fn sim(total_len: usize, shared_prefix: Option<(GroupId, usize)>) -> Self {
        PromptSpec {
            total_len,
            shared_prefix,
            tokens: None,
            shared_keys: OnceCell::new(),
            shared_keys_bs: Cell::new(0),
        }
    }

    pub fn real(tokens: Vec<Token>) -> Self {
        PromptSpec {
            total_len: tokens.len(),
            shared_prefix: None,
            tokens: Some(tokens),
            shared_keys: OnceCell::new(),
            shared_keys_bs: Cell::new(0),
        }
    }

    /// Content identity of the `i`-th `block_size`-token block of this
    /// request's sequence, for `owner` being this request's id.
    ///
    /// Two requests' blocks get equal keys iff the blocks hold identical
    /// token content, which is what prefix caching needs:
    ///   * real tokens  -> chain hash over token ids;
    ///   * sim + shared -> (group, index) within the shared region,
    ///                     (owner, index) beyond it;
    /// Chain hashing makes key_i depend on the whole prefix, like vLLM's
    /// APC block hashes, so divergent suffixes never collide.
    pub fn content_key(
        &self,
        owner: RequestId,
        block_index: usize,
        block_size: usize,
        prev_key: u128,
    ) -> u128 {
        let start = block_index * block_size;
        if let Some(tokens) = &self.tokens {
            let end = ((block_index + 1) * block_size).min(tokens.len());
            let mut h = prev_key ^ 0x517c_c1b7_2722_0a95;
            for &t in &tokens[start..end] {
                h = chain(h, t as u128);
            }
            // Partial final blocks are private to the owner (not shareable).
            if end - start < block_size {
                h = chain(h, 0x8000_0000_0000_0000_0000_0000_0000_0000u128 | owner as u128);
            }
            h
        } else {
            match self.shared_prefix {
                Some((group, shared_len)) if start + block_size <= shared_len => {
                    chain(prev_key, (group as u128) << 64 | block_index as u128)
                }
                _ => chain(
                    prev_key,
                    (1u128 << 120) | (owner as u128) << 32 | block_index as u128,
                ),
            }
        }
    }

    /// Content keys for the first `n_tokens` of the sequence.
    pub fn content_keys(
        &self,
        owner: RequestId,
        n_tokens: usize,
        block_size: usize,
    ) -> Vec<u128> {
        let n_blocks = n_tokens.div_ceil(block_size);
        let mut keys = Vec::with_capacity(n_blocks);
        let mut prev = 0u128;
        for i in 0..n_blocks {
            let k = self.content_key(owner, i, block_size, prev);
            keys.push(k);
            prev = k;
        }
        keys
    }

    /// Blocks whose content keys are owner-independent (shareable across
    /// requests): full token blocks on the real-token path, or blocks fully
    /// inside the sim shared-prefix region.
    fn shareable_blocks(&self, block_size: usize) -> usize {
        match (&self.tokens, self.shared_prefix) {
            (Some(tokens), _) => tokens.len() / block_size,
            (None, Some((_, shared_len))) => shared_len / block_size,
            (None, None) => 0,
        }
    }

    /// Leading owner-independent content keys (probed with owner 0): the
    /// router's prefix-affinity probe, and the shared head of every owner's
    /// full key path. Interned on first use — one chain-hash pass per
    /// prompt instance, carried along by `clone()`.
    pub fn affinity_keys(&self, block_size: usize) -> &[u128] {
        let keys = self.shared_keys.get_or_init(|| {
            self.shared_keys_bs.set(block_size);
            let n = self.shareable_blocks(block_size);
            let mut keys = Vec::with_capacity(n);
            let mut prev = 0u128;
            for i in 0..n {
                let k = self.content_key(0, i, block_size, prev);
                keys.push(k);
                prev = k;
            }
            keys
        });
        // Hard assert (not debug-only): silently returning keys computed
        // for a different block size would mean wrong KV content
        // addressing and phantom prefix hits. Block size is per-process
        // config today; heterogeneous-block-size fleets must recompute.
        assert_eq!(
            self.shared_keys_bs.get(),
            block_size,
            "affinity_keys called with two different block sizes"
        );
        keys
    }

    /// Content keys for the whole prompt (`total_len` tokens) of `owner` —
    /// identical to `content_keys(owner, total_len, block_size)` but reuses
    /// the interned shareable prefix and chain-hashes only the
    /// owner-private tail. Within the shareable region `content_key`
    /// ignores `owner`, so splicing the owner-0 prefix is exact.
    pub fn full_key_path(&self, owner: RequestId, block_size: usize) -> Vec<u128> {
        let n_blocks = self.total_len.div_ceil(block_size);
        let shared = self.affinity_keys(block_size);
        let take = shared.len().min(n_blocks);
        let mut keys = Vec::with_capacity(n_blocks);
        keys.extend_from_slice(&shared[..take]);
        let mut prev = keys.last().copied().unwrap_or(0);
        for i in take..n_blocks {
            let k = self.content_key(owner, i, block_size, prev);
            keys.push(k);
            prev = k;
        }
        keys
    }

    /// Drop the interned shareable-prefix keys (terminal request states;
    /// a later `affinity_keys` call recomputes them).
    fn release_interned(&mut self) {
        self.shared_keys.take();
        self.shared_keys_bs.set(0);
    }

    /// Whether the shareable-prefix keys are currently interned
    /// (cancellation tests assert terminal transitions drop them).
    pub(crate) fn has_interned(&self) -> bool {
        self.shared_keys.get().is_some()
    }
}

fn chain(prev: u128, x: u128) -> u128 {
    // 128-bit mix (two rounds of a xorshift-multiply).
    let mut h = prev ^ x.wrapping_mul(0x9E37_79B9_7F4A_7C15_F39C_C060_5CED_C835);
    h ^= h >> 67;
    h = h.wrapping_mul(0xC2B2_AE3D_27D4_EB4F_1656_67B1_9E37_79F9);
    h ^= h >> 59;
    h
}

/// Request lifecycle. Preempted = recompute-mode preemption (paper §6):
/// KV released; prompt + generated-so-far re-prefill when rescheduled.
/// Cancelled = client-side withdrawal through the serving API: terminal
/// like `Finished`, but the request produced no completion — its KV
/// interest, pool entry, and interned content keys are released at the
/// transition (see `Engine::cancel`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    Queued,
    Running,
    Preempted,
    Finished,
    Cancelled,
}

/// Inference phase (paper §2.1). `Prefill` covers first-time prompt
/// processing *and* recompute-mode re-prefill after preemption.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// One serving request, online or offline.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub class: TaskClass,
    pub arrival: f64,
    pub prompt: PromptSpec,
    pub max_new_tokens: usize,

    // ---- progress ----
    pub state: ReqState,
    pub phase: Phase,
    /// Positions whose KV is computed & resident on the device/simulated
    /// cache. Reset by preemption. Prefill targets `seq_len()` (for a
    /// resumed request that includes re-prefilling its generated tokens);
    /// in decode phase the invariant is `computed == seq_len() - 1` (the
    /// last emitted token's KV is written by the decode step consuming it).
    pub computed: usize,
    /// Output tokens emitted so far (survives preemption).
    pub generated: usize,
    /// Emitted token ids (real-model path; drives re-prefill content).
    pub out_tokens: Vec<Token>,

    // ---- latency bookkeeping ----
    pub first_token_at: Option<f64>,
    pub finished_at: Option<f64>,
    pub token_times: Vec<f64>,
    /// Times this request was preempted (recompute punishment accounting).
    pub preemptions: usize,

    // ---- interned derived state ----
    /// Cached full-prompt content-key path (see [`Request::content_key_path`]).
    key_path: OnceCell<Vec<u128>>,
    key_path_bs: Cell<usize>,
    /// How many times the key path was actually chain-hashed (regression
    /// guard: must stay at 1 across preempt → re-add → re-admit cycles).
    key_computes: Cell<u32>,
}

impl Request {
    pub fn new(
        id: RequestId,
        class: TaskClass,
        arrival: f64,
        prompt: PromptSpec,
        max_new_tokens: usize,
    ) -> Self {
        Request {
            id,
            class,
            arrival,
            prompt,
            max_new_tokens,
            state: ReqState::Queued,
            phase: Phase::Prefill,
            computed: 0,
            generated: 0,
            out_tokens: Vec::new(),
            first_token_at: None,
            finished_at: None,
            token_times: Vec::new(),
            preemptions: 0,
            key_path: OnceCell::new(),
            key_path_bs: Cell::new(0),
            key_computes: Cell::new(0),
        }
    }

    /// Interned content-key path covering the whole prompt
    /// (`prompt.total_len` tokens) — equal to
    /// `prompt.content_keys(id, prompt.total_len, block_size)` but computed
    /// at most once per request. Admission, preemption re-pooling,
    /// re-admission, KV registration, and completion all share this one
    /// vector instead of re-hashing the prompt.
    pub fn content_key_path(&self, block_size: usize) -> &[u128] {
        let keys = self.key_path.get_or_init(|| {
            self.key_path_bs.set(block_size);
            self.key_computes.set(self.key_computes.get() + 1);
            self.prompt.full_key_path(self.id, block_size)
        });
        // Hard assert for the same reason as `affinity_keys`: stale keys
        // under a changed block size must fail loudly, not corrupt cache
        // addressing.
        assert_eq!(
            self.key_path_bs.get(),
            block_size,
            "content_key_path called with two different block sizes"
        );
        keys
    }

    /// Times the key path was chain-hashed (test/regression hook).
    pub fn key_compute_count(&self) -> u32 {
        self.key_computes.get()
    }

    /// Whether any interned key vector (full path or shareable prefix) is
    /// still cached on this request — must be false after a terminal
    /// transition (finished / withdrawn / cancelled).
    pub fn has_interned_keys(&self) -> bool {
        self.key_path.get().is_some() || self.prompt.has_interned()
    }

    /// Drop the interned key caches. The store keeps every request forever
    /// for metrics, so terminal transitions (finished, withdrawn by
    /// work-stealing) must release the ~1 KB of key vectors nothing will
    /// read again; a later `content_key_path` call would recompute.
    pub fn release_interned_keys(&mut self) {
        self.key_path.take();
        self.key_path_bs.set(0);
        self.prompt.release_interned();
    }

    /// Total sequence length whose KV must exist before the next decode:
    /// prompt plus everything generated so far.
    pub fn seq_len(&self) -> usize {
        self.prompt.total_len + self.generated
    }

    /// Tokens still needing prefill (after recompute-mode preemption this
    /// includes previously generated tokens).
    pub fn remaining_prefill(&self) -> usize {
        if self.phase == Phase::Decode {
            0
        } else {
            self.seq_len().saturating_sub(self.computed)
        }
    }

    pub fn in_prefill(&self) -> bool {
        self.phase == Phase::Prefill
    }

    pub fn is_finished(&self) -> bool {
        self.state == ReqState::Finished
    }

    /// Deadline for this request's next output token under `slo`
    /// (paper §5.1: Latency_i = TTFT + i·TPOT, measured from arrival).
    pub fn next_token_deadline(&self, slo: &crate::core::Slo) -> f64 {
        self.arrival + slo.ttft + self.generated as f64 * slo.tpot
    }

    /// Reserve the output-token buffers for the whole output budget.
    /// Called at *admission* (not construction, so queued backlogs and the
    /// store's retained history never pay the footprint): from then on the
    /// engine's steady-state decode loop never reallocates a per-request
    /// buffer mid-step (the zero-alloc step invariant). Idempotent across
    /// preemption and re-admission.
    pub fn reserve_output(&mut self) {
        let want = self.max_new_tokens;
        self.token_times.reserve(want.saturating_sub(self.token_times.len()));
        self.out_tokens.reserve(want.saturating_sub(self.out_tokens.len()));
    }

    /// Record one emitted token at time `t` (prefill completion or a
    /// decode step); returns true if that completed the request. Does NOT
    /// advance `computed`: the emitted token's KV becomes resident only
    /// when the *next* decode step consumes it (the engine advances
    /// `computed` then).
    pub fn record_token(&mut self, t: f64, token: Option<Token>) -> bool {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(t);
        }
        self.phase = Phase::Decode;
        self.token_times.push(t);
        self.generated += 1;
        if let Some(tok) = token {
            self.out_tokens.push(tok);
        }
        if self.generated >= self.max_new_tokens {
            self.state = ReqState::Finished;
            self.finished_at = Some(t);
            true
        } else {
            false
        }
    }

    /// Recompute-mode preemption: KV is released, progress in `computed`
    /// resets, generated tokens are kept (they re-prefill later).
    pub fn preempt(&mut self) {
        debug_assert!(self.state == ReqState::Running);
        self.state = ReqState::Preempted;
        self.phase = Phase::Prefill;
        self.computed = 0;
        self.preemptions += 1;
    }

    /// TTFT if the first token has been emitted.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// Mean TPOT over the emitted tokens (needs >= 2 tokens).
    pub fn mean_tpot(&self) -> Option<f64> {
        if self.token_times.len() < 2 {
            return None;
        }
        let dt = self.token_times.last()? - self.token_times[0];
        Some(dt / (self.token_times.len() - 1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Slo;

    fn req(class: TaskClass) -> Request {
        Request::new(1, class, 10.0, PromptSpec::sim(100, None), 5)
    }

    #[test]
    fn lifecycle_counters() {
        let mut r = req(TaskClass::Online);
        assert_eq!(r.seq_len(), 100);
        assert_eq!(r.remaining_prefill(), 100);
        assert!(r.in_prefill());
        r.computed = 100; // prefill target reached -> emission
        assert!(!r.record_token(11.0, None));
        assert!(!r.in_prefill(), "emission flips to decode phase");
        assert_eq!(r.seq_len(), 101);
        assert_eq!(r.computed, r.seq_len() - 1, "decode-phase invariant");
        assert_eq!(r.generated, 1);
        assert_eq!(r.ttft().unwrap(), 1.0);
        for i in 0..4 {
            r.computed += 1; // decode step writes the consumed token's KV
            r.record_token(12.0 + i as f64, None);
        }
        assert!(r.is_finished());
        assert_eq!(r.finished_at, Some(15.0));
    }

    #[test]
    fn preemption_resets_computed_keeps_generated() {
        let mut r = req(TaskClass::Offline);
        r.state = ReqState::Running;
        r.computed = 100;
        r.record_token(11.0, None);
        r.record_token(12.0, None);
        r.preempt();
        assert_eq!(r.computed, 0);
        assert_eq!(r.generated, 2);
        assert_eq!(r.remaining_prefill(), 102);
        assert_eq!(r.preemptions, 1);
    }

    #[test]
    fn deadline_tracks_generated() {
        let slo = Slo {
            ttft: 1.0,
            tpot: 0.05,
        };
        let mut r = req(TaskClass::Online);
        assert_eq!(r.next_token_deadline(&slo), 11.0);
        r.computed = 100;
        r.record_token(10.5, None);
        assert!((r.next_token_deadline(&slo) - 11.05).abs() < 1e-12);
    }

    #[test]
    fn content_keys_share_within_group() {
        let a = PromptSpec::sim(64, Some((7, 48)));
        let b = PromptSpec::sim(80, Some((7, 48)));
        let c = PromptSpec::sim(64, Some((8, 48)));
        let ka = a.content_keys(1, 64, 16);
        let kb = b.content_keys(2, 80, 16);
        let kc = c.content_keys(3, 64, 16);
        // First 3 blocks (48 tokens) shared between a and b; not with c.
        assert_eq!(&ka[..3], &kb[..3]);
        assert_ne!(ka[3], kb[3]);
        assert_ne!(ka[0], kc[0]);
    }

    #[test]
    fn content_keys_real_tokens() {
        let a = PromptSpec::real(vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let b = PromptSpec::real(vec![1, 2, 3, 4, 9, 9, 9, 9]);
        let ka = a.content_keys(1, 8, 4);
        let kb = b.content_keys(2, 8, 4);
        assert_eq!(ka[0], kb[0]); // identical first block
        assert_ne!(ka[1], kb[1]); // divergent second block
    }

    #[test]
    fn chain_hash_depends_on_prefix() {
        // Same block content after different prefixes must differ.
        let a = PromptSpec::real(vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let b = PromptSpec::real(vec![9, 9, 9, 9, 5, 6, 7, 8]);
        let ka = a.content_keys(1, 8, 4);
        let kb = b.content_keys(2, 8, 4);
        assert_ne!(ka[1], kb[1]);
    }

    #[test]
    fn interned_path_matches_direct_hash() {
        // Sim + shared, sim private, real tokens, real with partial tail.
        let specs = vec![
            PromptSpec::sim(100, Some((7, 48))),
            PromptSpec::sim(100, None),
            PromptSpec::real((0..64).collect()),
            PromptSpec::real((0..70).collect()),
        ];
        for (owner, spec) in specs.into_iter().enumerate() {
            let owner = owner as RequestId + 1;
            let direct = spec.content_keys(owner, spec.total_len, 16);
            let interned = spec.full_key_path(owner, 16);
            assert_eq!(direct, interned, "owner {owner}");
            // Affinity keys are the owner-independent head of the path.
            let aff = spec.affinity_keys(16);
            assert_eq!(&direct[..aff.len().min(direct.len())], &aff[..aff.len().min(direct.len())]);
        }
    }

    #[test]
    fn key_path_computed_at_most_once() {
        let r = Request::new(9, TaskClass::Offline, 0.0, PromptSpec::sim(200, Some((3, 96))), 8);
        assert_eq!(r.key_compute_count(), 0);
        let first = r.content_key_path(16).to_vec();
        for _ in 0..5 {
            assert_eq!(r.content_key_path(16), &first[..]);
        }
        assert_eq!(r.key_compute_count(), 1, "path must be interned");
        assert_eq!(first, r.prompt.content_keys(9, 200, 16));
        // The cache survives cloning (same id, same prompt).
        let c = r.clone();
        assert_eq!(c.content_key_path(16), &first[..]);
        assert_eq!(c.key_compute_count(), 1);
    }

    #[test]
    fn partial_final_block_is_private() {
        let a = PromptSpec::real(vec![1, 2, 3, 4, 5, 6]);
        let b = PromptSpec::real(vec![1, 2, 3, 4, 5, 6]);
        let ka = a.content_keys(1, 6, 4);
        let kb = b.content_keys(2, 6, 4);
        assert_eq!(ka[0], kb[0]);
        assert_ne!(ka[1], kb[1]); // 2-token tail not shareable
    }
}

//! Deterministic fault injection and the failure vocabulary (PR 7).
//!
//! Echo's premise is over-provisioning for bursty online traffic — which
//! only pays off if the system *degrades* instead of wedging when replicas
//! die, backends hiccup, or load exceeds capacity (cf. ConServe's revocable
//! offline work and HyGen's SLO protection under stragglers, PAPERS.md).
//! This module defines:
//!
//! * [`FaultPlan`] — a seeded, virtual-clock-scheduled list of
//!   [`FaultEvent`]s (replica crash, slowdown window, transient execute
//!   errors, wire connection drop). Plans are plain data: the same seed
//!   always produces the same plan, and injection sites consume the plan on
//!   the virtual clock, so every fault fires at the same instant regardless
//!   of wall time or worker thread count.
//! * [`ReplicaFaults`] — the per-replica slice of a plan, installed into an
//!   `Engine` as an `Option` hook (absent = zero cost, same pattern as the
//!   trace ring).
//! * [`CancelReason`] — why a ticket was terminated without finishing; part
//!   of `TokenEvent::Cancelled` and the wire protocol.
//! * [`ServeError`] — the typed error vocabulary surfaced through the
//!   `Serve` trait (the vendored `anyhow` stub has no downcast, so
//!   classification happens *before* conversion: the engine retries
//!   transient faults internally and anything that escapes is
//!   replica-fatal).
//! * [`FaultStats`] — crash/recovery accounting the cluster reports.

use crate::utils::json::Json;
use crate::utils::rng::Rng;

/// Maximum consecutive attempts for one engine iteration's execute call
/// (1 initial + retries) before a transient fault escalates to replica
/// death.
pub const MAX_EXEC_ATTEMPTS: u32 = 4;

/// First retry backoff (virtual seconds); doubles per attempt.
pub const EXEC_BACKOFF_BASE: f64 = 0.01;

/// Backoff cap (virtual seconds).
pub const EXEC_BACKOFF_CAP: f64 = 0.08;

/// Total virtual-clock delay the capped exponential backoff adds for
/// `failures` consecutive failed attempts (attempt k waits
/// `min(BASE * 2^k, CAP)` before re-trying).
pub fn backoff_delay(failures: u32) -> f64 {
    let mut total = 0.0;
    for k in 0..failures {
        total += (EXEC_BACKOFF_BASE * f64::powi(2.0, k as i32)).min(EXEC_BACKOFF_CAP);
    }
    total
}

/// One scheduled fault. Times are virtual-clock seconds on the deployment
/// clock; `replica` is the replica id the fault targets (ids are assigned
/// in spawn order, so a plan is meaningful across runs of the same config).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    /// The replica dies at `at`: its engine stops mid-quantum, the
    /// coordinator detects the death at the next quantum boundary, retires
    /// its digest, reclaims its KV, and re-dispatches its in-flight work.
    Crash { at: f64, replica: usize },
    /// Straggler window: every execute between `at` and `until` takes
    /// `factor`× as long (virtual time), modelling thermal throttling or a
    /// noisy neighbor.
    Slowdown {
        at: f64,
        until: f64,
        replica: usize,
        factor: f64,
    },
    /// The next execute at or after `at` fails `failures` consecutive
    /// times before succeeding. `failures >= MAX_EXEC_ATTEMPTS` exhausts
    /// the retry budget and escalates to replica death.
    ExecError {
        at: f64,
        replica: usize,
        failures: u32,
    },
    /// A wire connection drops after serving `after_frames` request
    /// frames (connection-level; no replica target).
    ConnDrop { after_frames: u64 },
}

impl FaultEvent {
    pub fn replica(&self) -> Option<usize> {
        match *self {
            FaultEvent::Crash { replica, .. }
            | FaultEvent::Slowdown { replica, .. }
            | FaultEvent::ExecError { replica, .. } => Some(replica),
            FaultEvent::ConnDrop { .. } => None,
        }
    }
}

/// A seeded, deterministic schedule of faults.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    pub seed: u64,
}

impl FaultPlan {
    /// The empty plan (injection disabled).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Generate a random plan over `horizon` seconds targeting replicas
    /// `0..replicas`. Deterministic per seed. Densities are modest — the
    /// point is exercising recovery paths, not annihilating the fleet:
    /// up to one crash per two replicas, a couple of slowdown windows,
    /// a handful of transient execute errors (some past the retry budget
    /// so escalation paths run too).
    pub fn random(seed: u64, horizon: f64, replicas: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA_017_5EED);
        let mut events = Vec::new();
        if replicas == 0 || horizon <= 0.0 {
            return FaultPlan { events, seed };
        }
        let crashes = rng.range_usize(0, replicas / 2 + 1);
        for _ in 0..crashes {
            events.push(FaultEvent::Crash {
                at: rng.f64() * horizon,
                replica: rng.range_usize(0, replicas),
            });
        }
        let slowdowns = rng.range_usize(0, 3);
        for _ in 0..slowdowns {
            let at = rng.f64() * horizon * 0.8;
            events.push(FaultEvent::Slowdown {
                at,
                until: at + rng.f64() * horizon * 0.2 + 1e-3,
                replica: rng.range_usize(0, replicas),
                factor: 1.5 + rng.f64() * 6.5,
            });
        }
        let exec_errors = rng.range_usize(0, 5);
        for _ in 0..exec_errors {
            events.push(FaultEvent::ExecError {
                at: rng.f64() * horizon,
                replica: rng.range_usize(0, replicas),
                // Mostly transient (survive the retry budget), sometimes
                // fatal (escalate to crash-equivalent recovery).
                failures: if rng.bool(0.25) {
                    MAX_EXEC_ATTEMPTS
                } else {
                    rng.range_u64(1, MAX_EXEC_ATTEMPTS as u64) as u32
                },
            });
        }
        FaultPlan { events, seed }
    }

    /// Earliest scheduled crash for `replica`, if any.
    pub fn crash_time(&self, replica: usize) -> Option<f64> {
        self.events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::Crash { at, replica: r } if r == replica => Some(at),
                _ => None,
            })
            .fold(None, |acc: Option<f64>, t| {
                Some(acc.map_or(t, |a| a.min(t)))
            })
    }

    /// The per-replica slice of this plan (slowdown windows + transient
    /// execute errors, sorted by activation time). Crashes are coordinator
    /// business ([`FaultPlan::crash_time`]) and connection drops are wire
    /// business ([`FaultPlan::conn_drop`]); neither is installed in the
    /// engine.
    pub fn for_replica(&self, replica: usize) -> ReplicaFaults {
        let mut slowdowns = Vec::new();
        let mut exec = Vec::new();
        for e in &self.events {
            match *e {
                FaultEvent::Slowdown {
                    at,
                    until,
                    replica: r,
                    factor,
                } if r == replica => slowdowns.push((at, until, factor)),
                FaultEvent::ExecError {
                    at,
                    replica: r,
                    failures,
                } if r == replica => exec.push((at, failures)),
                _ => {}
            }
        }
        slowdowns.sort_by(|a, b| a.0.total_cmp(&b.0));
        exec.sort_by(|a, b| a.0.total_cmp(&b.0));
        ReplicaFaults {
            slowdowns,
            exec,
            next_exec: 0,
        }
    }

    /// A seeded plan of `conns` connection drops (PR 10 disconnect
    /// storms): each drop severs a wire connection after a small random
    /// number of served frames, exercising idempotent-resubmit and
    /// `stream {from_seq}` resume paths deterministically. Thresholds are
    /// in the plan's event order — chaos harnesses consume them one
    /// connection at a time.
    pub fn disconnect_storm(seed: u64, conns: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xD15C_0117_EC75);
        let events = (0..conns)
            .map(|_| FaultEvent::ConnDrop {
                after_frames: rng.range_u64(1, 6),
            })
            .collect();
        FaultPlan { events, seed }
    }

    /// First scheduled connection drop (frames-served threshold), if any.
    pub fn conn_drop(&self) -> Option<u64> {
        self.events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::ConnDrop { after_frames } => Some(after_frames),
                _ => None,
            })
            .min()
    }
}

/// The per-replica fault schedule an `Engine` consults around its execute
/// call. Installed as `Option<ReplicaFaults>`: absent costs one branch.
#[derive(Clone, Debug, Default)]
pub struct ReplicaFaults {
    /// `(from, until, factor)` straggler windows, sorted by `from`.
    slowdowns: Vec<(f64, f64, f64)>,
    /// `(at, failures)` transient execute faults, sorted by `at`, consumed
    /// in order as the clock passes them.
    exec: Vec<(f64, u32)>,
    next_exec: usize,
}

impl ReplicaFaults {
    pub fn is_empty(&self) -> bool {
        self.slowdowns.is_empty() && self.exec.is_empty()
    }

    /// Execution-time multiplier at virtual time `t` (1.0 outside every
    /// window; overlapping windows multiply).
    // lint: hot-path
    pub fn slow_factor(&self, t: f64) -> f64 {
        let mut f = 1.0;
        for &(from, until, factor) in &self.slowdowns {
            if from > t {
                break;
            }
            if t < until {
                f *= factor;
            }
        }
        f
    }

    /// Consume the next pending execute fault whose activation time has
    /// passed: the imminent execute should fail this many consecutive
    /// attempts. At most one fault fires per execute; queued-up faults
    /// fire on subsequent iterations.
    // lint: hot-path
    pub fn take_exec_failures(&mut self, t: f64) -> Option<u32> {
        if self.next_exec < self.exec.len() && self.exec[self.next_exec].0 <= t {
            let n = self.exec[self.next_exec].1;
            self.next_exec += 1;
            Some(n)
        } else {
            None
        }
    }
}

/// Why a ticket reached `Cancelled` instead of `Finished`. Carried on the
/// event and the wire so clients can distinguish their own withdrawal from
/// system-initiated termination.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// Client-requested withdrawal (the `cancel` verb / dropped receiver).
    Client,
    /// The request can never be scheduled (e.g. prompt exceeds KV memory).
    Unschedulable,
    /// The deployment stopped making progress and terminated remaining
    /// work instead of spinning (virtual-clock progress deadline).
    Stalled,
    /// Shed at admission under overload (offline work sheds first).
    ShedOverload,
    /// Rejected at the front door by the SLO-guard brownout ladder
    /// (PR 9): the fleet is protecting online attainment, the client
    /// should back off for the `retry_after` hint carried on the wire.
    Shed,
    /// Online work shed because its TTFT deadline had already expired
    /// while still queued under overload.
    DeadlineExpired,
    /// The owning replica died and the work could not be re-dispatched.
    ReplicaFailed,
}

impl CancelReason {
    pub fn as_str(self) -> &'static str {
        match self {
            CancelReason::Client => "client",
            CancelReason::Unschedulable => "unschedulable",
            CancelReason::Stalled => "stalled",
            CancelReason::ShedOverload => "shed_overload",
            CancelReason::Shed => "shed",
            CancelReason::DeadlineExpired => "deadline_expired",
            CancelReason::ReplicaFailed => "replica_failed",
        }
    }

    pub fn parse(s: &str) -> Option<CancelReason> {
        Some(match s {
            "client" => CancelReason::Client,
            "unschedulable" => CancelReason::Unschedulable,
            "stalled" => CancelReason::Stalled,
            "shed_overload" => CancelReason::ShedOverload,
            "shed" => CancelReason::Shed,
            "deadline_expired" => CancelReason::DeadlineExpired,
            "replica_failed" => CancelReason::ReplicaFailed,
            _ => return None,
        })
    }
}

/// Typed failure vocabulary for the serving stack. The vendored `anyhow`
/// stub offers no downcast, so callers that need to *classify* must do it
/// before the error crosses an `anyhow::Result` boundary; once it does,
/// the convention is: any error escaping a replica advance is
/// replica-fatal.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// An execute call kept failing past the retry budget.
    ExecFailed { attempts: u32, last: String },
    /// The engine's iteration backstop tripped (scheduling livelock).
    IterationBackstop { max_iterations: usize },
    /// The cluster drain backstop tripped (quantum livelock).
    QuantumBackstop { pumps: u64 },
    /// Coordinator bookkeeping referenced a replica that is not live
    /// (post-crash window; recoverable by re-dispatch).
    UnknownReplica { replica: usize },
    /// A wire frame exceeded the per-line size cap.
    FrameTooLarge { len: usize, max: usize },
    /// A wire connection died mid-line: `buffered` bytes of a partial
    /// frame were accepted before the transport failed (PR 10 — the loss
    /// is surfaced and accounted instead of silently discarded).
    FrameInterrupted { buffered: usize },
    /// The threaded server's coordinator is gone.
    ServerGone,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::ExecFailed { attempts, last } => write!(
                f,
                "backend execute failed {attempts} consecutive attempts \
                 (retry budget exhausted): {last}"
            ),
            ServeError::IterationBackstop { max_iterations } => {
                write!(f, "engine exceeded max_iterations {max_iterations}")
            }
            ServeError::QuantumBackstop { pumps } => {
                write!(f, "cluster drain exceeded the quantum backstop ({pumps} pumps)")
            }
            ServeError::UnknownReplica { replica } => {
                write!(f, "replica {replica} is not live")
            }
            ServeError::FrameTooLarge { len, max } => {
                write!(f, "frame too large: {len} bytes (cap {max})")
            }
            ServeError::FrameInterrupted { buffered } => {
                write!(
                    f,
                    "connection died mid-frame: {buffered} bytes of a \
                     partial frame discarded"
                )
            }
            ServeError::ServerGone => write!(f, "server coordinator is gone"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Crash/recovery accounting, reported by the cluster and merged into its
/// report JSON.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultStats {
    /// Replica deaths handled (scheduled crashes + escalated exec faults).
    pub crashes: usize,
    /// Online requests re-dispatched off dead replicas.
    pub online_redispatched: usize,
    /// Offline jobs returned to the backlog off dead replicas.
    pub offline_requeued: usize,
    /// Tokens of work lost to crashes that must be recomputed (prompt
    /// prefill already computed + output tokens already generated).
    pub tokens_recomputed: u64,
    /// Sum over crashes of (detection quantum boundary − crash instant):
    /// divide by `crashes` for mean time-to-recovery.
    pub recovery_time: f64,
    /// Offline tickets shed at admission under overload.
    pub shed_offline: usize,
    /// Queued online tickets shed after their TTFT deadline expired.
    pub shed_online: usize,
    /// Tickets terminated by the progress-deadline stall detector.
    pub stalled_cancels: usize,
}

impl FaultStats {
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("crashes", self.crashes)
            .set("online_redispatched", self.online_redispatched)
            .set("offline_requeued", self.offline_requeued)
            .set("tokens_recomputed", self.tokens_recomputed)
            .set("recovery_time", self.recovery_time)
            .set(
                "mean_time_to_recovery",
                if self.crashes == 0 {
                    0.0
                } else {
                    self.recovery_time / self.crashes as f64
                },
            )
            .set("shed_offline", self.shed_offline)
            .set("shed_online", self.shed_online)
            .set("stalled_cancels", self.stalled_cancels)
    }
}

/// Overload-shedding and liveness policy (cluster admission). When the
/// shared offline backlog exceeds `max_backlog`, the newest excess offline
/// tickets are shed (`ShedOverload`) — offline work is revocable by
/// contract, so it goes first. Queued online tickets older than
/// `online_grace`× the SLO TTFT are shed as `DeadlineExpired` instead of
/// queueing unboundedly. Both shedding knobs default to off (infinite);
/// `stall_after` defaults on because it only fires when the deployment is
/// provably frozen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShedPolicy {
    pub max_backlog: usize,
    /// Multiple of the SLO TTFT a queued online request may wait before
    /// being shed (`f64::INFINITY` = never shed online work).
    pub online_grace: f64,
    /// Virtual seconds a busy drain may go without any fleet progress
    /// (iterations, completions, cancellations, queue movement) before the
    /// remaining tickets are terminated as `Stalled` — the typed
    /// alternative to an infinite `drain` hang.
    pub stall_after: f64,
}

impl Default for ShedPolicy {
    fn default() -> Self {
        ShedPolicy {
            max_backlog: usize::MAX,
            online_grace: f64::INFINITY,
            stall_after: 16.0,
        }
    }
}

impl ShedPolicy {
    /// A policy that actively sheds (chaos/overload experiments).
    pub fn aggressive(max_backlog: usize, online_grace: f64) -> Self {
        ShedPolicy {
            max_backlog,
            online_grace,
            ..ShedPolicy::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_per_seed() {
        let a = FaultPlan::random(7, 60.0, 4);
        let b = FaultPlan::random(7, 60.0, 4);
        let c = FaultPlan::random(8, 60.0, 4);
        assert_eq!(a, b);
        assert!(a != c || a.is_empty() && c.is_empty());
        for e in &a.events {
            if let Some(r) = e.replica() {
                assert!(r < 4);
            }
        }
    }

    #[test]
    fn per_replica_slices_partition_the_plan() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent::Slowdown {
                    at: 1.0,
                    until: 2.0,
                    replica: 0,
                    factor: 3.0,
                },
                FaultEvent::ExecError {
                    at: 5.0,
                    replica: 1,
                    failures: 2,
                },
                FaultEvent::Crash { at: 9.0, replica: 0 },
                FaultEvent::Crash { at: 4.0, replica: 0 },
            ],
            seed: 0,
        };
        let f0 = plan.for_replica(0);
        assert!((f0.slow_factor(1.5) - 3.0).abs() < 1e-12);
        assert_eq!(f0.slow_factor(2.5), 1.0);
        let mut f1 = plan.for_replica(1);
        assert_eq!(f1.take_exec_failures(4.9), None);
        assert_eq!(f1.take_exec_failures(5.0), Some(2));
        assert_eq!(f1.take_exec_failures(100.0), None, "consumed once");
        assert_eq!(plan.crash_time(0), Some(4.0), "earliest crash wins");
        assert_eq!(plan.crash_time(1), None);
        assert!(plan.for_replica(2).is_empty());
    }

    #[test]
    fn overlapping_slowdowns_multiply() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent::Slowdown {
                    at: 0.0,
                    until: 10.0,
                    replica: 0,
                    factor: 2.0,
                },
                FaultEvent::Slowdown {
                    at: 5.0,
                    until: 6.0,
                    replica: 0,
                    factor: 3.0,
                },
            ],
            seed: 0,
        };
        let f = plan.for_replica(0);
        assert!((f.slow_factor(5.5) - 6.0).abs() < 1e-12);
        assert!((f.slow_factor(8.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn backoff_is_capped_exponential() {
        assert_eq!(backoff_delay(0), 0.0);
        assert!((backoff_delay(1) - 0.01).abs() < 1e-12);
        assert!((backoff_delay(2) - 0.03).abs() < 1e-12);
        // 0.01 + 0.02 + 0.04 + 0.08(capped) = 0.15
        assert!((backoff_delay(4) - 0.15).abs() < 1e-12);
        // further attempts add the cap only
        assert!((backoff_delay(5) - 0.23).abs() < 1e-12);
    }

    #[test]
    fn cancel_reason_round_trips() {
        for r in [
            CancelReason::Client,
            CancelReason::Unschedulable,
            CancelReason::Stalled,
            CancelReason::ShedOverload,
            CancelReason::Shed,
            CancelReason::DeadlineExpired,
            CancelReason::ReplicaFailed,
        ] {
            assert_eq!(CancelReason::parse(r.as_str()), Some(r));
        }
        assert_eq!(CancelReason::parse("nope"), None);
    }

    #[test]
    fn serve_error_displays_and_converts() {
        let e = ServeError::ExecFailed {
            attempts: 4,
            last: "boom".into(),
        };
        let a: anyhow::Error = e.into();
        assert!(a.to_string().contains("retry budget exhausted"));
        let b: anyhow::Error = ServeError::FrameTooLarge { len: 10, max: 4 }.into();
        assert!(b.to_string().contains("frame too large"));
    }

    #[test]
    fn conn_drop_picks_earliest_threshold() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent::ConnDrop { after_frames: 9 },
                FaultEvent::ConnDrop { after_frames: 3 },
            ],
            seed: 0,
        };
        assert_eq!(plan.conn_drop(), Some(3));
        assert_eq!(FaultPlan::none().conn_drop(), None);
    }

    #[test]
    fn disconnect_storms_are_seeded_and_bounded() {
        let a = FaultPlan::disconnect_storm(11, 8);
        let b = FaultPlan::disconnect_storm(11, 8);
        assert_eq!(a, b, "same seed, same storm");
        assert_eq!(a.events.len(), 8);
        for e in &a.events {
            match *e {
                FaultEvent::ConnDrop { after_frames } => {
                    assert!((1..=6).contains(&after_frames));
                }
                other => panic!("storms are pure ConnDrop plans: {other:?}"),
            }
        }
        assert_ne!(
            FaultPlan::disconnect_storm(12, 8),
            a,
            "different seed, different thresholds"
        );
    }

    #[test]
    fn fault_stats_export() {
        let mut s = FaultStats::default();
        assert!(!s.any());
        s.crashes = 2;
        s.recovery_time = 0.5;
        assert!(s.any());
        let j = s.to_json();
        assert_eq!(j.at("crashes").and_then(Json::as_u64), Some(2));
        let mttr = j.at("mean_time_to_recovery").and_then(Json::as_f64).unwrap();
        assert!((mttr - 0.25).abs() < 1e-12);
    }
}

//! Dataset synthesizers (paper §7.1 "Prompt datasets" + Table 1).
//!
//! The real datasets (ShareGPT, LooGLE, ToolBench, NExT-QA) are not
//! reachable offline; Echo consumes only their *structure* — prompt-length
//! distribution, output-length distribution, and prefix-sharing topology —
//! so each synthesizer is parameterized to reproduce the Table 1 row:
//!
//! | dataset   | mean prompt | shared rate |
//! |-----------|-------------|-------------|
//! | ShareGPT  |   308       |  < 5%       |
//! | LooGLE    | 23,474      |  91%        |
//! | ToolBench |  1,835      |  85%        |
//! | NExT-QA   |  9,865      |  88%        |
//!
//! Sharing topology: requests come in groups (one article/tool-doc/video →
//! several questions); within a group the first `shared_frac` of the prompt
//! is identical. Measured shared rate = shared_frac · (1 − 1/group_size),
//! so group sizes are chosen to land on the paper's numbers.

use crate::core::{PromptSpec, Request, RequestId, RequestStore, TaskClass, Token};
use crate::utils::rng::Rng;

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Mean prompt length (tokens) and lognormal sigma of the multiplier.
    pub mean_prompt: usize,
    pub prompt_sigma: f64,
    /// Fraction of each prompt shared within its group (leading prefix).
    pub shared_frac: f64,
    /// Requests per sharing group (0/1 = no sharing).
    pub group_size: usize,
    /// Output length: mean and lognormal sigma.
    pub mean_out: usize,
    pub out_sigma: f64,
}

impl DatasetSpec {
    pub fn sharegpt() -> Self {
        DatasetSpec {
            name: "ShareGPT",
            mean_prompt: 308,
            prompt_sigma: 0.6,
            shared_frac: 0.05,
            group_size: 4, // 0.05·(1−1/4) ≈ 3.8% < 5%
            mean_out: 180,
            out_sigma: 0.5,
        }
    }

    pub fn loogle() -> Self {
        DatasetSpec {
            name: "LooGLE",
            mean_prompt: 23_474,
            prompt_sigma: 0.25,
            shared_frac: 0.958,
            group_size: 20, // 0.958·(19/20) ≈ 91.0%
            mean_out: 64,
            out_sigma: 0.4,
        }
    }

    /// LooGLE QA_Short / QA_Long evaluation subsets (§7.1): same sharing
    /// topology, different prompt-length scale.
    pub fn loogle_qa_short() -> Self {
        DatasetSpec {
            name: "LooGLE QA_Short",
            mean_prompt: 8_000,
            ..Self::loogle()
        }
    }

    pub fn loogle_qa_long() -> Self {
        DatasetSpec {
            name: "LooGLE QA_Long",
            mean_prompt: 23_474,
            ..Self::loogle()
        }
    }

    pub fn toolbench() -> Self {
        DatasetSpec {
            name: "ToolBench",
            mean_prompt: 1_835,
            prompt_sigma: 0.35,
            shared_frac: 0.903,
            group_size: 17, // 0.903·(16/17) ≈ 85.0%
            mean_out: 96,
            out_sigma: 0.4,
        }
    }

    pub fn nextqa() -> Self {
        DatasetSpec {
            name: "NExT-QA",
            mean_prompt: 9_865,
            prompt_sigma: 0.3,
            shared_frac: 0.932,
            group_size: 18, // 0.932·(17/18) ≈ 88.0%
            mean_out: 48,
            out_sigma: 0.4,
        }
    }

    /// Scale all token counts by `f` (used to shrink workloads onto the
    /// CPU/EchoLM testbed while keeping ratios).
    pub fn scaled(mut self, f: f64) -> Self {
        self.mean_prompt = ((self.mean_prompt as f64 * f) as usize).max(4);
        self.mean_out = ((self.mean_out as f64 * f) as usize).max(2);
        self
    }

    fn sample_len(&self, rng: &mut Rng, mean: usize, sigma: f64) -> usize {
        // lognormal with E = mean: mu = ln(mean) - sigma^2/2
        let mu = (mean as f64).ln() - sigma * sigma / 2.0;
        (rng.lognormal(mu, sigma).round() as usize).clamp(2, mean * 8)
    }
}

/// A batch of synthesized requests (ids already assigned via the store).
pub struct SyntheticBatch {
    pub ids: Vec<RequestId>,
    /// Tokens in shared prefixes counted once vs total (Table 1 measure).
    pub total_tokens: u64,
    pub unique_tokens: u64,
}

impl SyntheticBatch {
    /// Measured prefix-sharing rate (Table 1's "Shared Rate").
    pub fn shared_rate(&self) -> f64 {
        if self.total_tokens == 0 {
            0.0
        } else {
            1.0 - self.unique_tokens as f64 / self.total_tokens as f64
        }
    }
}

/// Synthesize `n` requests of `spec` into `store` with `class` and a fixed
/// `arrival`. Group ids are globally unique (derived from the rng stream).
pub fn synthesize(
    spec: &DatasetSpec,
    n: usize,
    class: TaskClass,
    arrival: f64,
    store: &mut RequestStore,
    rng: &mut Rng,
) -> SyntheticBatch {
    let mut ids = Vec::with_capacity(n);
    let mut total = 0u64;
    let mut unique = 0u64;
    let mut made = 0usize;
    while made < n {
        let group = if spec.group_size > 1 {
            Some(rng.next_u64() | 1)
        } else {
            None
        };
        // Group-wide shared prefix length from one article-scale draw.
        let base_len = spec.sample_len(rng, spec.mean_prompt, spec.prompt_sigma);
        let shared_len = (base_len as f64 * spec.shared_frac) as usize;
        let members = if spec.group_size > 1 {
            spec.group_size.min(n - made)
        } else {
            1
        };
        for m in 0..members {
            // Each member: shared prefix + its own question tail, sized so
            // the expected prompt length stays at mean_prompt and the
            // expected shared fraction at shared_frac.
            let tail_mean = ((spec.mean_prompt as f64) * (1.0 - spec.shared_frac))
                .round()
                .max(1.0) as usize;
            let tail = spec.sample_len(rng, tail_mean, spec.prompt_sigma).max(1);
            let prompt_len = if group.is_some() { shared_len + tail } else { base_len };
            let out_len = spec.sample_len(rng, spec.mean_out, spec.out_sigma);
            let id = store.fresh_id();
            let prompt = match group {
                Some(g) => PromptSpec::sim(prompt_len, Some((g, shared_len))),
                None => PromptSpec::sim(prompt_len, None),
            };
            store.insert(Request::new(id, class, arrival, prompt, out_len));
            ids.push(id);
            total += prompt_len as u64;
            unique += if group.is_some() {
                (if m == 0 { shared_len } else { 0 } + tail) as u64
            } else {
                prompt_len as u64
            };
            made += 1;
        }
    }
    SyntheticBatch {
        ids,
        total_tokens: total,
        unique_tokens: unique,
    }
}

/// Real-token workload for the PJRT/EchoLM path: short prompts over the
/// EchoLM vocabulary, optionally sharing a literal token prefix.
pub fn synthesize_real(
    n: usize,
    prompt_len: usize,
    shared_groups: usize,
    shared_len: usize,
    out_len: usize,
    vocab: u32,
    class: TaskClass,
    arrival: f64,
    store: &mut RequestStore,
    rng: &mut Rng,
) -> Vec<RequestId> {
    assert!(shared_len <= prompt_len);
    // Pre-draw shared prefixes.
    let prefixes: Vec<Vec<Token>> = (0..shared_groups.max(1))
        .map(|_| {
            (0..shared_len)
                .map(|_| rng.range_u64(0, (vocab - 1) as u64) as Token)
                .collect()
        })
        .collect();
    (0..n)
        .map(|i| {
            let mut tokens = if shared_groups > 0 && shared_len > 0 {
                prefixes[i % shared_groups].clone()
            } else {
                Vec::new()
            };
            while tokens.len() < prompt_len {
                tokens.push(rng.range_u64(0, (vocab - 1) as u64) as Token);
            }
            let id = store.fresh_id();
            store.insert(Request::new(
                id,
                class,
                arrival,
                PromptSpec::real(tokens),
                out_len,
            ));
            id
        })
        .collect()
}

/// All four Table 1 rows.
pub fn table1_specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec::sharegpt(),
        DatasetSpec::loogle(),
        DatasetSpec::toolbench(),
        DatasetSpec::nextqa(),
    ]
}

/// Chaos overlay for a workload run: a seeded fault plan sized to the
/// replay (`horizon` sim-seconds over `replicas` fleet members, scaled by
/// `intensity` — 1.0 is the chaos suite's default density, 0.0 disables).
/// A trace overlay rather than part of the trace: the same workload can be
/// replayed fault-free or under any chaos seed without regenerating
/// arrivals, which is what the fault-free-equivalence tests rely on.
pub fn chaos_overlay(
    seed: u64,
    horizon: f64,
    replicas: usize,
    intensity: f64,
) -> crate::faults::FaultPlan {
    if intensity <= 0.0 || replicas == 0 || horizon <= 0.0 {
        return crate::faults::FaultPlan::none();
    }
    let mut plan = crate::faults::FaultPlan::random(seed, horizon, replicas);
    if intensity < 1.0 {
        // Thin deterministically: keep a stable prefix of each event kind
        // rather than sampling, so lowering intensity only removes faults.
        let keep = (plan.events.len() as f64 * intensity).ceil() as usize;
        plan.events.truncate(keep);
    } else if intensity > 1.0 {
        let extra = intensity.ceil() as usize - 1;
        for i in 0..extra {
            let more = crate::faults::FaultPlan::random(
                seed.wrapping_add(1 + i as u64),
                horizon,
                replicas,
            );
            plan.events.extend(more.events);
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn measure(spec: &DatasetSpec, n: usize) -> (f64, f64) {
        let mut store = RequestStore::new();
        let mut rng = Rng::new(5);
        let b = synthesize(spec, n, TaskClass::Offline, 0.0, &mut store, &mut rng);
        let mean_prompt = store
            .iter()
            .map(|r| r.prompt.total_len as f64)
            .sum::<f64>()
            / store.len() as f64;
        (mean_prompt, b.shared_rate())
    }

    #[test]
    fn sharegpt_matches_table1() {
        let (mean, rate) = measure(&DatasetSpec::sharegpt(), 2000);
        assert!((mean - 308.0).abs() / 308.0 < 0.25, "mean {mean}");
        assert!(rate < 0.05, "rate {rate}");
    }

    #[test]
    fn loogle_matches_table1() {
        let (mean, rate) = measure(&DatasetSpec::loogle(), 1000);
        assert!((mean - 23_474.0).abs() / 23_474.0 < 0.30, "mean {mean}");
        assert!((rate - 0.91).abs() < 0.04, "rate {rate}");
    }

    #[test]
    fn toolbench_matches_table1() {
        let (_, rate) = measure(&DatasetSpec::toolbench(), 2000);
        assert!((rate - 0.85).abs() < 0.04, "rate {rate}");
    }

    #[test]
    fn nextqa_matches_table1() {
        let (_, rate) = measure(&DatasetSpec::nextqa(), 2000);
        assert!((rate - 0.88).abs() < 0.04, "rate {rate}");
    }

    #[test]
    fn groups_share_content_keys() {
        let mut store = RequestStore::new();
        let mut rng = Rng::new(1);
        let b = synthesize(
            &DatasetSpec::loogle_qa_short(),
            20,
            TaskClass::Offline,
            0.0,
            &mut store,
            &mut rng,
        );
        // All members of a group share leading blocks.
        let mut by_group: std::collections::HashMap<u64, Vec<RequestId>> = Default::default();
        for &id in &b.ids {
            if let Some((g, _)) = store.get(id).prompt.shared_prefix {
                by_group.entry(g).or_default().push(id);
            }
        }
        let (_, members) = by_group.iter().next().unwrap();
        assert!(members.len() >= 2);
        let k0 = store.get(members[0]).content_key_path(16);
        let k1 = store.get(members[1]).content_key_path(16);
        assert_eq!(k0[..2], k1[..2], "same group must share leading keys");
    }

    #[test]
    fn real_tokens_share_prefix_literally() {
        let mut store = RequestStore::new();
        let mut rng = Rng::new(2);
        let ids = synthesize_real(
            4, 32, 2, 16, 8, 512, TaskClass::Offline, 0.0, &mut store, &mut rng,
        );
        let t0 = store.get(ids[0]).prompt.tokens.clone().unwrap();
        let t2 = store.get(ids[2]).prompt.tokens.clone().unwrap();
        assert_eq!(t0[..16], t2[..16], "groups 0 and 2 share prefix");
        assert_eq!(t0.len(), 32);
    }

    #[test]
    fn scaled_keeps_structure() {
        let s = DatasetSpec::loogle_qa_short().scaled(0.01);
        assert_eq!(s.mean_prompt, 80);
        assert!(s.shared_frac > 0.9);
    }
}

//! Echo: efficient co-scheduling of hybrid online-offline tasks for LLM serving.
//!
//! Reproduction of the paper's three-component system — KV-cache-aware task
//! scheduler, task-aware KV cache manager, and estimation toolkits — as a
//! rust coordinator (layer 3) driving an AOT-compiled JAX/Pallas model
//! (layers 2/1) through the PJRT C API. See DESIGN.md for the inventory and
//! EXPERIMENTS.md for the paper-vs-measured record.

// Curated crate-level lint posture (PR 8). Repo-specific invariants —
// determinism, zero-alloc hot paths, unwrap hygiene — are enforced by the
// in-tree analyzer (`analysis`, `echo lint`); these cover what rustc and
// clippy can check natively. `unsafe_code` is denied except under the
// `runtime` feature, whose PJRT handle needs one `unsafe impl Send`.
#![deny(non_ascii_idents)]
#![cfg_attr(not(feature = "runtime"), deny(unsafe_code))]
#![warn(clippy::dbg_macro, clippy::todo, clippy::unimplemented)]

pub mod analysis;
pub mod cluster;
pub mod config;
pub mod core;
pub mod engine;
pub mod estimator;
pub mod faults;
pub mod figures;
pub mod kvcache;
pub mod metrics;
pub mod obs;
#[cfg(feature = "runtime")]
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod server;
pub mod slo;
pub mod sim;
pub mod trace;
pub mod utils;
pub mod workload;

mod cli;
pub use cli::run_cli;

//! PJRT runtime: loads the AOT artifacts (`make artifacts`) and executes
//! EchoLM steps on the PJRT CPU client. Python never runs here — the HLO
//! text + weights.bin + manifest.json are the entire interface (see
//! python/compile/aot.py for the producing side and the argument-order
//! contract).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::utils::json::Json;

/// One parameter tensor's manifest row.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub byte_offset: usize,
    pub byte_len: usize,
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub max_batch: usize,
    pub kv_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub weights_bytes: usize,
    /// chunk width -> HLO file name
    pub buckets: BTreeMap<usize, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let cfg = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let u = |p: &str| -> Result<usize> {
            cfg.get(p)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest config missing {p}"))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    byte_offset: p
                        .get("byte_offset")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("param missing byte_offset"))?,
                    byte_len: p
                        .get("byte_len")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("param missing byte_len"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut buckets = BTreeMap::new();
        for b in j
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing buckets"))?
        {
            let chunk = b
                .get("chunk")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("bucket missing chunk"))?;
            let hlo = b
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("bucket missing hlo"))?;
            buckets.insert(chunk, hlo.to_string());
        }
        Ok(Manifest {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            n_layers: u("n_layers")?,
            max_seq: u("max_seq")?,
            max_batch: u("max_batch")?,
            kv_shape: j
                .get("kv_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest missing kv_shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            params,
            weights_bytes: j
                .get("weights_bytes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing weights_bytes"))?,
            buckets,
        })
    }
}

/// Output of one model step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// Greedy next token per slot (garbage for inactive slots).
    pub next_tokens: Vec<i32>,
    /// Last-position logits per slot, row-major [B, vocab].
    pub logits: Vec<f32>,
}

/// The loaded model: compiled executables per chunk bucket + device-held
/// weights, with the KV slab threaded between steps.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    weights: Vec<xla::Literal>,
    /// KV slab literal [L, 2, B, H, S, Dh]; replaced after every step.
    kv: xla::Literal,
    kv_dims: Vec<usize>,
}

// SAFETY: the xla crate's handles use Rc + raw PJRT pointers, making them
// !Send by default. Every Rc clone (client handles inside executables)
// lives inside this struct, so moving the *whole* ModelRuntime to another
// thread transfers all owners together; it is never shared across threads
// (the server moves it into the single coordinator thread at spawn). The
// PJRT CPU client itself is safe to use from the thread that owns it.
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    /// Load artifacts and compile every bucket on the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelRuntime> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        // Weights.
        let blob = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading {}/weights.bin", dir.display()))?;
        if blob.len() != manifest.weights_bytes {
            bail!(
                "weights.bin is {} bytes, manifest says {}",
                blob.len(),
                manifest.weights_bytes
            );
        }
        let mut weights = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let bytes = &blob[p.byte_offset..p.byte_offset + p.byte_len];
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &p.shape,
                bytes,
            )?;
            weights.push(lit);
        }

        // Executables.
        let mut executables = BTreeMap::new();
        for (&chunk, hlo) in &manifest.buckets {
            let path = dir.join(hlo);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(chunk, exe);
        }
        if executables.is_empty() {
            bail!("no buckets in manifest");
        }

        let kv_dims = manifest.kv_shape.clone();
        let kv = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &kv_dims);
        Ok(ModelRuntime {
            manifest,
            client,
            executables,
            weights,
            kv,
            kv_dims,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Chunk buckets available, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        self.executables.keys().copied().collect()
    }

    /// Smallest bucket that fits `chunk` tokens.
    pub fn bucket_for(&self, chunk: usize) -> Result<usize> {
        self.executables
            .keys()
            .copied()
            .find(|&b| b >= chunk)
            .ok_or_else(|| anyhow!("no bucket fits chunk {chunk}"))
    }

    /// Zero the KV slab (fresh serving session).
    pub fn reset_kv(&mut self) {
        self.kv = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &self.kv_dims);
    }

    /// Execute one step in the given bucket.
    ///
    /// `tokens` is row-major [max_batch, bucket_chunk]; `cache_lens` and
    /// `q_lens` are per-slot. Inactive slots: q_len 0. The KV slab advances
    /// in place (slots addressed by index).
    pub fn step(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        cache_lens: &[i32],
        q_lens: &[i32],
    ) -> Result<StepOutput> {
        let b = self.manifest.max_batch;
        if tokens.len() != b * bucket || cache_lens.len() != b || q_lens.len() != b {
            bail!(
                "step shape mismatch: tokens {} (want {}), lens {}/{}",
                tokens.len(),
                b * bucket,
                cache_lens.len(),
                q_lens.len()
            );
        }
        for i in 0..b {
            let end = cache_lens[i] + q_lens[i];
            if cache_lens[i] < 0 || q_lens[i] < 0 || end as usize > self.manifest.max_seq {
                bail!(
                    "slot {i}: cache_len {} + q_len {} exceeds max_seq {}",
                    cache_lens[i],
                    q_lens[i],
                    self.manifest.max_seq
                );
            }
        }
        let exe = self
            .executables
            .get(&bucket)
            .ok_or_else(|| anyhow!("unknown bucket {bucket}"))?;

        let tokens_lit = xla::Literal::vec1(tokens).reshape(&[b as i64, bucket as i64])?;
        let cache_lit = xla::Literal::vec1(cache_lens);
        let qlens_lit = xla::Literal::vec1(q_lens);

        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&self.kv);
        args.push(&tokens_lit);
        args.push(&cache_lit);
        args.push(&qlens_lit);

        let result = exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let (next_lit, logits_lit, kv_lit) = out.to_tuple3()?;
        self.kv = kv_lit;
        Ok(StepOutput {
            next_tokens: next_lit.to_vec::<i32>()?,
            logits: logits_lit.to_vec::<f32>()?,
        })
    }

    /// Wall-clock micro-benchmark of a bucket with all slots active at a
    /// given context length — feeds the estimator's coefficient fitting.
    pub fn bench_step(&mut self, bucket: usize, context: usize, reps: usize) -> Result<f64> {
        let b = self.manifest.max_batch;
        let tokens = vec![1i32; b * bucket];
        let cache = vec![context as i32; b];
        let q = vec![bucket as i32; b];
        // warmup
        self.step(bucket, &tokens, &cache, &q)?;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            self.step(bucket, &tokens, &cache, &q)?;
        }
        Ok(t0.elapsed().as_secs_f64() / reps as f64)
    }
}

//! Comment- and string-aware Rust lexer for echo-lint.
//!
//! Hand-rolled on purpose: `syn` is not reachable offline, and the rules
//! only need a token stream with line numbers, not a syntax tree. The
//! lexer understands exactly as much Rust as it takes to never mistake a
//! string or comment for code: line comments, nested block comments, raw
//! and byte strings (`r"…"`, `r#"…"#`, `br…`, `b"…"`), escaped quotes,
//! char literals vs lifetimes. Everything else is idents, numbers, and
//! single-char puncts (`::` is two `:` tokens; rules sequence-match).
//!
//! Comments are collected separately from tokens because the directive
//! grammar (see [`super::rules`]) lives in comments, while every code
//! rule works on the token stream and can therefore never fire on
//! commented-out or quoted text.

/// Token class. `Life` is a lifetime (`'a`), distinct from `Char` (`'a'`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Life,
    Punct,
}

/// One code token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// One comment (line or block, full text) with its 1-based start line.
#[derive(Clone, Debug)]
pub struct CommentTok {
    pub text: String,
    pub line: usize,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    is_ident_start(c) || c.is_ascii_digit()
}

fn span(s: &[char], a: usize, b: usize) -> String {
    s[a.min(s.len())..b.min(s.len())].iter().collect()
}

/// Find the closing quote of a raw string: a `"` followed by `hashes` `#`s.
fn raw_close(s: &[char], from: usize, hashes: usize) -> Option<usize> {
    let n = s.len();
    let fence = |k: usize| s[k + 1..k + 1 + hashes].iter().all(|&h| h == '#');
    let mut j = from;
    while j < n {
        if s[j] == '"' && j + 1 + hashes <= n && fence(j) {
            return Some(j);
        }
        j += 1;
    }
    None
}

/// Tokenize `src` into (tokens, comments), both carrying 1-based lines.
///
/// Unterminated strings/comments run to end of file rather than erroring:
/// the linter must keep scanning a broken tree, not die on it.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<CommentTok>) {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<CommentTok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment (covers `///` and `//!` doc comments too)
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            comments.push(CommentTok {
                text: span(&s, i, j),
                line,
            });
            i = j;
            continue;
        }
        // block comment, nested
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if s[i] == '/' && i + 1 < n && s[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if s[i] == '*' && i + 1 < n && s[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push(CommentTok {
                text: span(&s, start, i),
                line: start_line,
            });
            continue;
        }
        // raw / byte strings: r"…", r#"…"#, br#"…"#, b"…"
        if c == 'r' || c == 'b' {
            let mut p = i + 1;
            if c == 'b' && p < n && s[p] == 'r' {
                p += 1;
            }
            let hash_start = p;
            while p < n && s[p] == '#' {
                p += 1;
            }
            let hashes = p - hash_start;
            if p < n && s[p] == '"' {
                let (text, next) = match raw_close(&s, p + 1, hashes) {
                    Some(j) => (span(&s, i, j + 1 + hashes), j + 1 + hashes),
                    None => (span(&s, i, n), n),
                };
                // count newlines from the whole token AFTER recording its
                // start line, so multi-line raw strings never drift lines
                let newlines = text.chars().filter(|&ch| ch == '\n').count();
                toks.push(Tok {
                    kind: TokKind::Str,
                    text,
                    line,
                });
                line += newlines;
                i = next;
                continue;
            }
            // not a raw-string head: fall through to the ident branch
        }
        // plain string; skip `\x` escape pairs so `\"` never closes it
        if c == '"' {
            let start_line = line;
            let mut j = i + 1;
            while j < n {
                if s[j] == '\\' {
                    j += 2;
                    continue;
                }
                if s[j] == '"' {
                    break;
                }
                j += 1;
            }
            let text = span(&s, i, j + 1);
            // escape pairs can hide `\`-newline continuations: count the
            // newlines from the finished token text, not during the scan
            let newlines = text.chars().filter(|&ch| ch == '\n').count();
            toks.push(Tok {
                kind: TokKind::Str,
                text,
                line: start_line,
            });
            line += newlines;
            i = j + 1;
            continue;
        }
        // `'a'` is a char, `'a` is a lifetime; `'ab'` and longer are never
        // chars in Rust, so an ident run longer than one char is a lifetime
        if c == '\'' {
            if i + 1 < n && is_ident_start(s[i + 1]) {
                let mut k = i + 2;
                while k < n && is_ident_cont(s[k]) {
                    k += 1;
                }
                if k < n && s[k] == '\'' && k == i + 2 {
                    toks.push(Tok {
                        kind: TokKind::Char,
                        text: span(&s, i, k + 1),
                        line,
                    });
                    i = k + 1;
                } else {
                    toks.push(Tok {
                        kind: TokKind::Life,
                        text: span(&s, i, k),
                        line,
                    });
                    i = k;
                }
                continue;
            }
            // escaped (`'\n'`) or punct (`'{'`) char literal
            let mut j = i + 1;
            if j < n && s[j] == '\\' {
                j += 2;
            }
            while j < n && s[j] != '\'' {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Char,
                text: span(&s, i, j + 1),
                line,
            });
            i = j + 1;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_cont(s[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: span(&s, i, j),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && is_ident_cont(s[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: span(&s, i, j),
                line,
            });
            i = j;
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    (toks, comments)
}

/// Literal value of a string token: strips the optional `r`/`br`/`b`
/// prefix, the `#` fencing, and the quotes. Escapes are left unresolved —
/// the rules only compare paths and JSON keys, which never contain them.
pub fn str_value(text: &str) -> String {
    for pre in ["br", "r", "b", ""] {
        let Some(rest) = text.strip_prefix(pre) else {
            continue;
        };
        let hashes = rest.chars().take_while(|&c| c == '#').count();
        let fenced = &rest[hashes..];
        let Some(inner) = fenced.strip_prefix('"') else {
            continue;
        };
        let close = format!("\"{}", "#".repeat(hashes));
        if let Some(body) = inner.strip_suffix(close.as_str()) {
            return body.to_string();
        }
    }
    text.trim_matches('"').to_string()
}

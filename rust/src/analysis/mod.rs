//! echo-lint — repo-invariant static analysis (PR 8).
//!
//! A dependency-free analyzer that enforces at CI time the invariants the
//! repo otherwise only states in prose: simulation determinism (no wall
//! clock, no std hash-order iteration), zero-alloc hot paths, unwrap
//! hygiene, oracle test coverage, microbench gate coverage, and
//! DESIGN.md/doc drift. See DESIGN.md "Static analysis (PR 8)" for the
//! rule catalog and the directive grammar, [`rules`] for semantics, and
//! [`lexer`] for the token model.
//!
//! Entry points: `echo lint` (CLI) and [`lint_repo`] (in-process — the
//! `repo_is_lint_clean` tier-1 test runs the same pass `cargo test` side).

pub mod lexer;
pub mod rules;

pub use lexer::{lex, str_value, CommentTok, Tok, TokKind};
pub use rules::{run, Finding, LintFile, LintInput, LintOutcome, SuppressedFinding, RULE_NAMES};

use crate::utils::json::Json;
use std::path::{Path, PathBuf};

/// Schema version of `LINT_REPORT.json`.
pub const REPORT_VERSION: u64 = 1;

/// Full result of linting a repo checkout, serializable to
/// `LINT_REPORT.json` (byte-stable: findings are sorted, objects use
/// ordered keys).
#[derive(Debug)]
pub struct LintReport {
    pub root: PathBuf,
    pub outcome: LintOutcome,
}

impl LintReport {
    /// True when there are zero unsuppressed findings.
    pub fn ok(&self) -> bool {
        self.outcome.findings.is_empty()
    }

    /// Unsuppressed finding count per rule, in [`RULE_NAMES`] order.
    pub fn counts(&self) -> Vec<(&'static str, usize)> {
        RULE_NAMES
            .iter()
            .map(|&rule| {
                let n = self.outcome.findings.iter().filter(|f| f.rule == rule).count();
                (rule, n)
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self.outcome.findings.iter().map(finding_json).collect();
        let suppressed: Vec<Json> = self
            .outcome
            .suppressed
            .iter()
            .map(|s| finding_json(&s.finding).set("reason", s.reason.as_str()))
            .collect();
        let mut counts = Json::obj();
        for (rule, n) in self.counts() {
            if n > 0 {
                counts = counts.set(rule, n);
            }
        }
        Json::obj()
            .set("version", REPORT_VERSION)
            .set("root", self.root.display().to_string())
            .set("files_scanned", self.outcome.files_scanned)
            .set("ok", self.ok())
            .set("counts", counts)
            .set("findings", Json::Arr(findings))
            .set("suppressed", Json::Arr(suppressed))
    }
}

fn finding_json(f: &Finding) -> Json {
    Json::obj()
        .set("rule", f.rule)
        .set("file", f.file.as_str())
        .set("line", f.line)
        .set("message", f.message.as_str())
}

/// Walk upward from the CWD to the first directory containing `rust/src`.
pub fn find_root() -> anyhow::Result<PathBuf> {
    let mut dir = std::env::current_dir()?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            anyhow::bail!("no rust/src found in the CWD or any parent; pass --root");
        }
    }
}

fn collect_rs(base: &Path, dir: &Path, out: &mut Vec<(String, String)>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            collect_rs(base, &p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            let rel = match p.strip_prefix(base) {
                Ok(r) => r.to_string_lossy().replace('\\', "/"),
                Err(_) => p.display().to_string(),
            };
            let text = std::fs::read_to_string(&p)?;
            out.push((rel, text));
        }
    }
    Ok(())
}

/// Read the repo at `root` from disk and run every rule: all of
/// `rust/src/**/*.rs` (sorted by relative path for deterministic output),
/// `rust/tests/*.rs` for oracle coverage, `rust/benches/microbench.rs`
/// for gate coverage, and `DESIGN.md` for doc drift.
pub fn lint_repo(root: &Path) -> anyhow::Result<LintReport> {
    let src_base = root.join("rust").join("src");
    if !src_base.is_dir() {
        anyhow::bail!("{} is not an echo repo root (no rust/src)", root.display());
    }
    let mut src = Vec::new();
    collect_rs(&src_base, &src_base, &mut src)?;
    src.sort_by(|a, b| a.0.cmp(&b.0));

    let mut tests = Vec::new();
    let tdir = root.join("rust").join("tests");
    if tdir.is_dir() {
        collect_rs(&tdir, &tdir, &mut tests)?;
        tests.sort_by(|a, b| a.0.cmp(&b.0));
    }

    let mb = root.join("rust").join("benches").join("microbench.rs");
    let microbench = if mb.is_file() {
        Some(std::fs::read_to_string(&mb)?)
    } else {
        None
    };
    let design = std::fs::read_to_string(root.join("DESIGN.md")).unwrap_or_default();

    let outcome = rules::run(&LintInput {
        src,
        tests,
        microbench,
        design,
    });
    Ok(LintReport {
        root: root.to_path_buf(),
        outcome,
    })
}

//! echo-lint rule engine: directives, regions, and the rule families.
//!
//! Directive grammar (all inside comments; the word "lint" followed by a
//! colon marks one — spelled out here rather than written literally so
//! this file stays clean under its own scanner):
//!
//!   * `allow-<rule>(reason)` suppresses `<rule>` on the directive's own
//!     line or the line directly below. An empty reason or an unknown
//!     rule name is itself a finding (rule id `directive`), so every
//!     suppression in the tree carries a justification.
//!   * `hot-path` marks the next `fn` at or below the directive; the
//!     `alloc` rule then bans allocating calls inside its brace-matched
//!     body.
//!
//! `#[cfg(test)]` regions are exempt from the per-line rules: tests may
//! unwrap, allocate, and use std maps freely.
//!
//! Rule families (ids as they appear in reports and suppressions):
//!   std-map         std HashMap/HashSet outside `utils/hash.rs`
//!   wall-clock      Instant/SystemTime/thread/env reads outside the
//!                   wall-clock allowlist (server/, runtime/, serve/wire.rs,
//!                   engine/pjrt.rs)
//!   alloc           allocating calls in hot-path function bodies
//!   unwrap          `.unwrap()` / `.expect(` in non-test code
//!   oracle-coverage every `Oracle*` type referenced from `rust/tests/`
//!   gate-coverage   every microbench path gated or documented ungated
//!   doc-drift       wire verbs + metrics keys present in DESIGN.md
//!   directive       malformed or reason-less directives

use super::lexer::{lex, str_value, CommentTok, Tok, TokKind};
use crate::utils::hash::FxHashSet;

/// Every rule id, in report order. `directive` is internal: it cannot be
/// suppressed (a broken suppression must not be able to excuse itself).
pub const RULE_NAMES: [&str; 8] = [
    "std-map",
    "wall-clock",
    "alloc",
    "unwrap",
    "oracle-coverage",
    "gate-coverage",
    "doc-drift",
    "directive",
];

/// One diagnostic. `file` is relative to `rust/src` for source findings;
/// cross-file rules use repo-relative paths (e.g. `rust/benches/…`).
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub rule: &'static str,
    pub line: usize,
    pub message: String,
}

/// A finding silenced by a per-site `allow-` directive, with its reason.
#[derive(Clone, Debug)]
pub struct SuppressedFinding {
    pub finding: Finding,
    pub reason: String,
}

/// Everything the rules read. Built from disk by [`super::lint_repo`], or
/// assembled in-memory by the analyzer's own tests.
#[derive(Debug, Default)]
pub struct LintInput {
    /// `(rel_path, text)` for every `.rs` under `rust/src`, rel to it.
    pub src: Vec<(String, String)>,
    /// `(name, text)` for every `.rs` directly under `rust/tests`.
    pub tests: Vec<(String, String)>,
    /// Text of `rust/benches/microbench.rs`, if present.
    pub microbench: Option<String>,
    /// Text of `DESIGN.md` (empty when missing).
    pub design: String,
}

/// Result of a full run: unsuppressed findings (sorted by file, line,
/// rule, message) and the suppressed ones with their reasons.
#[derive(Debug)]
pub struct LintOutcome {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub suppressed: Vec<SuppressedFinding>,
}

// ------------------------------------------------------------ directives

struct Directives {
    /// `(rule, line, reason)` per valid `allow-` site.
    allows: Vec<(&'static str, usize, String)>,
    /// Lines carrying a `hot-path` directive.
    hots: Vec<usize>,
    /// `(line, message)` for malformed directives.
    bad: Vec<(usize, String)>,
}

// String literals are invisible to the directive scanner (it reads
// comments only), so the marker can be spelled plainly here.
const MARKER: &str = "lint:";

fn find_from(chars: &[char], from: usize, needle: &str) -> Option<usize> {
    let pat: Vec<char> = needle.chars().collect();
    let mut p = from;
    while p + pat.len() <= chars.len() {
        if chars[p..p + pat.len()] == pat[..] {
            return Some(p);
        }
        p += 1;
    }
    None
}

fn starts_with_at(chars: &[char], at: usize, needle: &str) -> bool {
    let pat: Vec<char> = needle.chars().collect();
    at + pat.len() <= chars.len() && chars[at..at + pat.len()] == pat[..]
}

fn canonical_rule(name: &str) -> Option<&'static str> {
    RULE_NAMES.iter().copied().find(|r| *r == name)
}

fn parse_directives(comments: &[CommentTok]) -> Directives {
    let mut d = Directives {
        allows: Vec::new(),
        hots: Vec::new(),
        bad: Vec::new(),
    };
    for c in comments {
        if !c.text.contains(MARKER) {
            continue;
        }
        let chars: Vec<char> = c.text.chars().collect();
        let mut matched = false;
        // hot-path: the marker, optional whitespace, `hot-path`, boundary
        let mut p = 0usize;
        while let Some(at) = find_from(&chars, p, MARKER) {
            let mut q = at + MARKER.len();
            while q < chars.len() && chars[q].is_whitespace() {
                q += 1;
            }
            if starts_with_at(&chars, q, "hot-path") {
                let after = q + "hot-path".len();
                let boundary = after >= chars.len()
                    || !(chars[after].is_ascii_alphanumeric() || chars[after] == '_');
                if boundary {
                    d.hots.push(c.line);
                    matched = true;
                }
            }
            p = at + MARKER.len();
        }
        // allow-<rule>(reason): non-overlapping, a match consumes its span
        let mut p = 0usize;
        while let Some(at) = find_from(&chars, p, MARKER) {
            p = at + MARKER.len();
            let mut q = p;
            while q < chars.len() && chars[q].is_whitespace() {
                q += 1;
            }
            if !starts_with_at(&chars, q, "allow-") {
                continue;
            }
            let name_start = q + "allow-".len();
            let mut e = name_start;
            while e < chars.len()
                && (chars[e].is_ascii_lowercase() || chars[e].is_ascii_digit() || chars[e] == '-')
            {
                e += 1;
            }
            if e == name_start || e >= chars.len() || chars[e] != '(' {
                continue;
            }
            let Some(close_off) = chars[e + 1..].iter().position(|&ch| ch == ')') else {
                continue;
            };
            let rule: String = chars[name_start..e].iter().collect();
            let reason = chars[e + 1..e + 1 + close_off]
                .iter()
                .collect::<String>()
                .trim()
                .to_string();
            matched = true;
            p = e + 2 + close_off;
            match canonical_rule(&rule) {
                None | Some("directive") => {
                    d.bad.push((c.line, format!("unknown rule in allow-{rule}")));
                }
                Some(r) => {
                    if reason.is_empty() {
                        d.bad.push((c.line, format!("allow-{r} missing a reason")));
                    } else {
                        d.allows.push((r, c.line, reason));
                    }
                }
            }
        }
        if !matched {
            d.bad.push((c.line, "malformed lint directive".to_string()));
        }
    }
    d
}

// --------------------------------------------------------------- regions

type Region = (usize, usize);

/// Start/end lines of the brace pair opening at `toks[start_idx]`.
fn brace_region(toks: &[Tok], start_idx: usize) -> Region {
    let start_line = toks[start_idx].line;
    let mut depth = 0i64;
    for t in &toks[start_idx..] {
        if t.kind == TokKind::Punct {
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    return (start_line, t.line);
                }
            }
        }
    }
    (start_line, toks.last().map_or(start_line, |t| t.line))
}

fn hot_regions(toks: &[Tok], hots: &[usize], bad: &mut Vec<(usize, String)>) -> Vec<Region> {
    let mut regions = Vec::new();
    for &hline in hots {
        let fn_idx = toks
            .iter()
            .position(|t| t.line >= hline && t.kind == TokKind::Ident && t.text == "fn");
        let Some(fn_idx) = fn_idx else {
            bad.push((hline, "hot-path directive without a following fn".to_string()));
            continue;
        };
        let brace = toks[fn_idx..]
            .iter()
            .position(|t| t.kind == TokKind::Punct && t.text == "{")
            .map(|off| fn_idx + off);
        let Some(brace) = brace else {
            bad.push((hline, "hot-path fn without a body".to_string()));
            continue;
        };
        regions.push(brace_region(toks, brace));
    }
    regions
}

fn cfg_test_regions(toks: &[Tok]) -> Vec<Region> {
    let mut regions = Vec::new();
    for k in 0..toks.len().saturating_sub(4) {
        let is_cfg_test = toks[k].kind == TokKind::Ident
            && toks[k].text == "cfg"
            && toks[k + 1].kind == TokKind::Punct
            && toks[k + 1].text == "("
            && toks[k + 2].kind == TokKind::Ident
            && toks[k + 2].text == "test"
            && toks[k + 3].kind == TokKind::Punct
            && toks[k + 3].text == ")";
        if !is_cfg_test {
            continue;
        }
        if let Some(off) = toks[k + 4..]
            .iter()
            .position(|t| t.kind == TokKind::Punct && t.text == "{")
        {
            regions.push(brace_region(toks, k + 4 + off));
        }
    }
    regions
}

fn in_regions(line: usize, regions: &[Region]) -> bool {
    regions.iter().any(|&(a, b)| a <= line && line <= b)
}

// ----------------------------------------------------------- line rules

type Pat = &'static [(TokKind, Option<&'static str>)];

fn seq_match(toks: &[Tok], k: usize, pat: &[(TokKind, Option<&str>)]) -> bool {
    if k + pat.len() > toks.len() {
        return false;
    }
    pat.iter().enumerate().all(|(off, &(kind, text))| {
        let t = &toks[k + off];
        t.kind == kind && text.map_or(true, |x| t.text == x)
    })
}

const COLON: (TokKind, Option<&'static str>) = (TokKind::Punct, Some(":"));

const WALL_CLOCK_SEQS: [(&str, Pat); 5] = [
    (
        "Instant::now",
        &[(TokKind::Ident, Some("Instant")), COLON, COLON, (TokKind::Ident, Some("now"))],
    ),
    (
        "SystemTime::now",
        &[(TokKind::Ident, Some("SystemTime")), COLON, COLON, (TokKind::Ident, Some("now"))],
    ),
    (
        "thread::current",
        &[(TokKind::Ident, Some("thread")), COLON, COLON, (TokKind::Ident, Some("current"))],
    ),
    (
        "env::var",
        &[(TokKind::Ident, Some("env")), COLON, COLON, (TokKind::Ident, Some("var"))],
    ),
    (
        "env::var_os",
        &[(TokKind::Ident, Some("env")), COLON, COLON, (TokKind::Ident, Some("var_os"))],
    ),
];

const ALLOC_SEQS: [(&str, Pat); 7] = [
    (
        "Vec::new",
        &[(TokKind::Ident, Some("Vec")), COLON, COLON, (TokKind::Ident, Some("new"))],
    ),
    ("vec! macro", &[(TokKind::Ident, Some("vec")), (TokKind::Punct, Some("!"))]),
    (
        "Box::new",
        &[(TokKind::Ident, Some("Box")), COLON, COLON, (TokKind::Ident, Some("new"))],
    ),
    ("format! macro", &[(TokKind::Ident, Some("format")), (TokKind::Punct, Some("!"))]),
    (
        ".to_vec()",
        &[
            (TokKind::Punct, Some(".")),
            (TokKind::Ident, Some("to_vec")),
            (TokKind::Punct, Some("(")),
        ],
    ),
    (
        ".collect()",
        &[
            (TokKind::Punct, Some(".")),
            (TokKind::Ident, Some("collect")),
            (TokKind::Punct, Some("(")),
        ],
    ),
    (
        ".clone()",
        &[
            (TokKind::Punct, Some(".")),
            (TokKind::Ident, Some("clone")),
            (TokKind::Punct, Some("(")),
        ],
    ),
];

const UNWRAP_SEQS: [(&str, Pat); 2] = [
    (
        ".unwrap()",
        &[
            (TokKind::Punct, Some(".")),
            (TokKind::Ident, Some("unwrap")),
            (TokKind::Punct, Some("(")),
        ],
    ),
    (
        ".expect()",
        &[
            (TokKind::Punct, Some(".")),
            (TokKind::Ident, Some("expect")),
            (TokKind::Punct, Some("(")),
        ],
    ),
];

const WALL_CLOCK_ALLOW_FILES: [&str; 2] = ["serve/wire.rs", "engine/pjrt.rs"];
const WALL_CLOCK_ALLOW_DIRS: [&str; 2] = ["server/", "runtime/"];

/// One parsed source file with its directives and regions resolved.
#[derive(Debug)]
pub struct LintFile {
    pub rel: String,
    pub toks: Vec<Tok>,
    allows: Vec<(&'static str, usize, String)>,
    hot: Vec<Region>,
    test: Vec<Region>,
    bad: Vec<(usize, String)>,
}

impl LintFile {
    pub fn parse(rel: &str, src: &str) -> LintFile {
        let (toks, comments) = lex(src);
        let d = parse_directives(&comments);
        let mut bad = d.bad;
        let hot = hot_regions(&toks, &d.hots, &mut bad);
        let test = cfg_test_regions(&toks);
        LintFile {
            rel: rel.to_string(),
            toks,
            allows: d.allows,
            hot,
            test,
            bad,
        }
    }

    fn allow_reason(&self, rule: &str, line: usize) -> Option<&str> {
        self.allows
            .iter()
            .find(|(r, ln, _)| *r == rule && *ln == line)
            .or_else(|| {
                self.allows
                    .iter()
                    .find(|(r, ln, _)| *r == rule && *ln + 1 == line)
            })
            .map(|(_, _, reason)| reason.as_str())
    }
}

fn finding(file: &str, rule: &'static str, line: usize, message: String) -> Finding {
    Finding {
        file: file.to_string(),
        rule,
        line,
        message,
    }
}

fn line_rule_findings(f: &LintFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let toks = &f.toks;
    if !f.rel.ends_with("utils/hash.rs") {
        for t in toks {
            if t.kind == TokKind::Ident
                && (t.text == "HashMap" || t.text == "HashSet")
                && !in_regions(t.line, &f.test)
            {
                out.push(finding(
                    &f.rel,
                    "std-map",
                    t.line,
                    format!("use Fx{} from utils::hash instead of std {}", t.text, t.text),
                ));
            }
        }
    }
    let wall_allowed = WALL_CLOCK_ALLOW_FILES.contains(&f.rel.as_str())
        || WALL_CLOCK_ALLOW_DIRS.iter().any(|d| f.rel.starts_with(d));
    if !wall_allowed {
        for k in 0..toks.len() {
            for (name, pat) in &WALL_CLOCK_SEQS {
                if seq_match(toks, k, pat) && !in_regions(toks[k].line, &f.test) {
                    out.push(finding(
                        &f.rel,
                        "wall-clock",
                        toks[k].line,
                        format!("{name} breaks virtual-clock determinism"),
                    ));
                }
            }
        }
    }
    if !f.hot.is_empty() {
        for k in 0..toks.len() {
            for (name, pat) in &ALLOC_SEQS {
                if seq_match(toks, k, pat)
                    && in_regions(toks[k].line, &f.hot)
                    && !in_regions(toks[k].line, &f.test)
                {
                    out.push(finding(
                        &f.rel,
                        "alloc",
                        toks[k].line,
                        format!("{name} in a hot-path function"),
                    ));
                }
            }
        }
    }
    for k in 0..toks.len() {
        for (name, pat) in &UNWRAP_SEQS {
            if seq_match(toks, k, pat) && !in_regions(toks[k].line, &f.test) {
                out.push(finding(
                    &f.rel,
                    "unwrap",
                    toks[k].line,
                    format!("{name} in non-test code"),
                ));
            }
        }
    }
    for (ln, msg) in &f.bad {
        out.push(finding(&f.rel, "directive", *ln, msg.clone()));
    }
    out
}

// ---------------------------------------------------------- cross-file

fn oracle_rule(files: &[LintFile], test_idents: &FxHashSet<String>) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        for k in 0..f.toks.len().saturating_sub(1) {
            let decl = f.toks[k].kind == TokKind::Ident
                && matches!(f.toks[k].text.as_str(), "struct" | "enum" | "trait")
                && f.toks[k + 1].kind == TokKind::Ident
                && f.toks[k + 1].text.starts_with("Oracle");
            if decl && !test_idents.contains(&f.toks[k + 1].text) {
                out.push(finding(
                    &f.rel,
                    "oracle-coverage",
                    f.toks[k + 1].line,
                    format!(
                        "{} is not referenced from any rust/tests/ file",
                        f.toks[k + 1].text
                    ),
                ));
            }
        }
    }
    out
}

/// String literals inside the bracketed initializer of `const <name>`.
/// Skips to the `=` first: the type annotation may also contain brackets.
fn const_str_list(toks: &[Tok], name: &str) -> Vec<(String, usize)> {
    let mut vals = Vec::new();
    for k in 1..toks.len() {
        let is_decl = toks[k].kind == TokKind::Ident
            && toks[k].text == name
            && toks[k - 1].kind == TokKind::Ident
            && toks[k - 1].text == "const";
        if !is_decl {
            continue;
        }
        let mut eq = k;
        while eq < toks.len() && !(toks[eq].kind == TokKind::Punct && toks[eq].text == "=") {
            eq += 1;
        }
        let mut depth = 0i64;
        let mut started = false;
        for t in &toks[eq..] {
            if t.kind == TokKind::Punct && t.text == "[" {
                depth += 1;
                started = true;
            } else if t.kind == TokKind::Punct && t.text == "]" {
                depth -= 1;
                if started && depth == 0 {
                    return vals;
                }
            } else if started && t.kind == TokKind::Str {
                vals.push((str_value(&t.text), t.line));
            }
        }
        return vals;
    }
    vals
}

/// `(path, line)` for every literal second argument of a `.bench(` or
/// `.bench_fixed(` call. Non-literal (forwarded) paths are skipped.
fn bench_paths(toks: &[Tok]) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for k in 0..toks.len().saturating_sub(2) {
        let call = toks[k].kind == TokKind::Punct
            && toks[k].text == "."
            && toks[k + 1].kind == TokKind::Ident
            && (toks[k + 1].text == "bench" || toks[k + 1].text == "bench_fixed")
            && toks[k + 2].kind == TokKind::Punct
            && toks[k + 2].text == "(";
        if !call {
            continue;
        }
        let mut depth = 0i64;
        let mut args: Vec<Vec<&Tok>> = vec![Vec::new()];
        for t in &toks[k + 2..] {
            let p = t.kind == TokKind::Punct;
            if p && (t.text == "(" || t.text == "[" || t.text == "{") {
                depth += 1;
                if depth == 1 {
                    continue;
                }
            } else if p && (t.text == ")" || t.text == "]" || t.text == "}") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if p && t.text == "," && depth == 1 {
                args.push(Vec::new());
                continue;
            }
            if depth >= 1 {
                if let Some(last) = args.last_mut() {
                    last.push(t);
                }
            }
        }
        if args.len() >= 2 && args[1].len() == 1 && args[1][0].kind == TokKind::Str {
            out.push((str_value(&args[1][0].text), args[1][0].line));
        }
    }
    out
}

const MICROBENCH_REL: &str = "rust/benches/microbench.rs";

fn gate_rule(microbench: Option<&str>) -> Vec<Finding> {
    let Some(src) = microbench else {
        return vec![finding(
            MICROBENCH_REL,
            "gate-coverage",
            1,
            "microbench.rs not found".to_string(),
        )];
    };
    let (toks, _) = lex(src);
    let gated = const_str_list(&toks, "GATED_PAIRS");
    let ungated_raw = const_str_list(&toks, "UNGATED_PAIRS");
    // UNGATED_PAIRS string literals alternate (path, reason)
    let mut ungated: Vec<(&(String, usize), &(String, usize))> = Vec::new();
    let mut i = 0;
    while i + 1 < ungated_raw.len() {
        ungated.push((&ungated_raw[i], &ungated_raw[i + 1]));
        i += 2;
    }
    if gated.is_empty() && ungated.is_empty() {
        return vec![finding(
            MICROBENCH_REL,
            "gate-coverage",
            1,
            "GATED_PAIRS/UNGATED_PAIRS manifests missing".to_string(),
        )];
    }
    let mut out = Vec::new();
    let in_gated = |v: &str| gated.iter().any(|(g, _)| g == v);
    let in_ungated = |v: &str| ungated.iter().any(|((u, _), _)| u == v);
    let calls = bench_paths(&toks);
    let called = |v: &str| calls.iter().any(|(c, _)| c == v);
    for (v, ln) in &calls {
        if !in_gated(v) && !in_ungated(v) {
            out.push(finding(
                MICROBENCH_REL,
                "gate-coverage",
                *ln,
                format!("bench path \"{v}\" is neither gated nor in the documented ungated list"),
            ));
        }
    }
    for (v, ln) in &gated {
        if !called(v) {
            out.push(finding(
                MICROBENCH_REL,
                "gate-coverage",
                *ln,
                format!("GATED_PAIRS entry \"{v}\" matches no bench call"),
            ));
        }
    }
    for ((v, ln), (reason, rln)) in &ungated {
        if !called(v) {
            out.push(finding(
                MICROBENCH_REL,
                "gate-coverage",
                *ln,
                format!("UNGATED_PAIRS entry \"{v}\" matches no bench call"),
            ));
        }
        if reason.trim().is_empty() {
            out.push(finding(
                MICROBENCH_REL,
                "gate-coverage",
                *rln,
                format!("UNGATED_PAIRS entry \"{v}\" has an empty reason"),
            ));
        }
    }
    out
}

fn doc_rule(files: &[LintFile], design: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let set_call: Pat = &[
        (TokKind::Punct, Some(".")),
        (TokKind::Ident, Some("set")),
        (TokKind::Punct, Some("(")),
    ];
    for f in files {
        if f.rel != "serve/wire.rs" {
            continue;
        }
        let toks = &f.toks;
        for k in 0..toks.len().saturating_sub(5) {
            let is_verb = seq_match(toks, k, set_call)
                && toks[k + 3].kind == TokKind::Str
                && str_value(&toks[k + 3].text) == "verb"
                && toks[k + 4].kind == TokKind::Punct
                && toks[k + 4].text == ","
                && toks[k + 5].kind == TokKind::Str;
            if !is_verb {
                continue;
            }
            let v = str_value(&toks[k + 5].text);
            if !design.contains(&format!("\"verb\":\"{v}\"")) {
                out.push(finding(
                    &f.rel,
                    "doc-drift",
                    toks[k + 5].line,
                    format!("wire verb \"{v}\" missing from DESIGN.md wire grammar"),
                ));
            }
        }
    }
    for f in files {
        if f.rel != "metrics/mod.rs" {
            continue;
        }
        let toks = &f.toks;
        for k in 0..toks.len().saturating_sub(3) {
            if !(seq_match(toks, k, set_call) && toks[k + 3].kind == TokKind::Str) {
                continue;
            }
            let ln = toks[k + 3].line;
            if in_regions(ln, &f.test) {
                continue;
            }
            let key = str_value(&toks[k + 3].text);
            if !design.contains(&format!("`{key}`")) {
                out.push(finding(
                    &f.rel,
                    "doc-drift",
                    ln,
                    format!("Metrics::to_json key `{key}` missing from DESIGN.md schema"),
                ));
            }
        }
    }
    out
}

// ------------------------------------------------------------------ run

/// Run every rule over `input`, apply suppressions, sort deterministically.
pub fn run(input: &LintInput) -> LintOutcome {
    let files: Vec<LintFile> = input
        .src
        .iter()
        .map(|(rel, text)| LintFile::parse(rel, text))
        .collect();
    let mut all: Vec<Finding> = Vec::new();
    for f in &files {
        all.extend(line_rule_findings(f));
    }
    let mut test_idents: FxHashSet<String> = FxHashSet::default();
    for (_, text) in &input.tests {
        let (toks, _) = lex(text);
        for t in toks {
            if t.kind == TokKind::Ident {
                test_idents.insert(t.text);
            }
        }
    }
    all.extend(oracle_rule(&files, &test_idents));
    all.extend(gate_rule(input.microbench.as_deref()));
    all.extend(doc_rule(&files, &input.design));
    all.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.message.as_str()).cmp(&(
            b.file.as_str(),
            b.line,
            b.rule,
            b.message.as_str(),
        ))
    });
    let mut findings = Vec::new();
    let mut suppressed = Vec::new();
    for fnd in all {
        let reason = files
            .iter()
            .find(|f| f.rel == fnd.file)
            .and_then(|f| f.allow_reason(fnd.rule, fnd.line))
            .map(str::to_string);
        match reason {
            Some(reason) if fnd.rule != "directive" => {
                suppressed.push(SuppressedFinding {
                    finding: fnd,
                    reason,
                });
            }
            _ => findings.push(fnd),
        }
    }
    LintOutcome {
        files_scanned: files.len(),
        findings,
        suppressed,
    }
}

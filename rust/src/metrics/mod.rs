//! Serving metrics: per-request latency (TTFT/TPOT), throughput, SLO
//! attainment, and the time series behind Figures 8-11 (active requests,
//! memory breakdown, prefix-cache hit ratio, predictor traces).

use crate::core::TaskClass;
use crate::utils::json::Json;
use crate::utils::stats::{LogHistogram, Summary, TimeSeries};

/// Snapshot cadence control: long simulations sample series sparsely.
#[derive(Clone, Copy, Debug)]
pub struct SampleCtl {
    min_interval: f64,
    last: f64,
}

impl SampleCtl {
    pub fn new(min_interval: f64) -> Self {
        SampleCtl {
            min_interval,
            last: f64::NEG_INFINITY,
        }
    }

    pub fn due(&mut self, t: f64) -> bool {
        if t - self.last >= self.min_interval {
            self.last = t;
            true
        } else {
            false
        }
    }

    /// Re-anchor the cadence: treat `t` as the most recent sample instant,
    /// so the next sample falls due at `t + min_interval`. Mid-run
    /// reconfiguration (`Engine::set_sample_interval`) threads the previous
    /// anchor through this instead of resetting to "immediately due", which
    /// keeps sparse series sampling from drifting when a cluster quantum
    /// grid does not divide the interval.
    pub fn reset(&mut self, t: f64) {
        self.last = t;
    }

    /// The most recent sample instant (`NEG_INFINITY` before the first).
    pub fn last_sample(&self) -> f64 {
        self.last
    }
}

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    // ---- per-request latency (online) ----
    pub online_ttft: Vec<f64>,
    pub online_tpot: Vec<f64>,
    // ---- completions & token counts ----
    pub online_completed: usize,
    pub offline_completed: usize,
    pub online_tokens_out: u64,
    pub offline_tokens_out: u64,
    /// Billed tokens (prompt + output) of completed offline requests — the
    /// batch-API work unit behind the paper's offline-throughput metric
    /// (benefit = tokens processed, Eq. 1; a cache-hit prefix still counts:
    /// the request's tokens were served, just without recompute).
    pub offline_billed_tokens: u64,
    /// Prefill tokens actually computed (recompute shows up here).
    pub prefill_tokens_computed: u64,
    /// Prefill tokens skipped via prefix-cache fast-forward.
    pub prefill_tokens_saved: u64,
    // ---- per-token SLO attainment (paper §5.1: token i's deadline is
    // arrival + TTFT + i·TPOT; a token is attained if it lands by then) ----
    pub online_tokens_checked: u64,
    pub online_token_deadlines_met: u64,
    // ---- engine counters ----
    pub iterations: usize,
    pub busy_time: f64,
    pub preemptions: usize,
    pub skipped_offline: usize,
    /// Requests withdrawn through the serving API before completion
    /// (dropped clients, explicit `cancel` verbs, harvested offline work).
    pub cancelled_online: usize,
    pub cancelled_offline: usize,
    // ---- fault/recovery counters (PR 7) ----
    /// Failed `ExecutionBackend::execute` attempts (injected or real)
    /// absorbed by the engine's retry loop or escalated past it.
    pub exec_faults: u64,
    /// Iterations that recovered via retry after at least one failed
    /// execute attempt.
    pub exec_retries: u64,
    // ---- time series (Figures 8-10) ----
    pub active_online: TimeSeries,
    pub active_offline: TimeSeries,
    pub mem_running: TimeSeries,
    pub mem_cached_online: TimeSeries,
    pub mem_cached_offline: TimeSeries,
    pub mem_free: TimeSeries,
    pub hit_ratio: TimeSeries,
    /// Cumulative prefix-lookup / hit block counts (windowed ratios for
    /// Fig. 9 are differenced from these).
    pub cache_lookups_cum: TimeSeries,
    pub cache_hits_cum: TimeSeries,
    pub online_arrivals: TimeSeries,
    // ---- streaming percentile histograms (PR 6 observability) ----
    // Log-bucketed and mergeable, so cluster aggregation yields true fleet
    // percentiles instead of engine-local sample vectors.
    pub ttft_hist: LogHistogram,
    pub tpot_hist: LogHistogram,
    /// Online admission wait (admission clock - arrival), seconds.
    pub queue_wait_hist: LogHistogram,
    /// Estimator audit: |predicted - actual| / actual per executed
    /// iteration (recorded only when the estimator produced a prediction).
    pub est_rel_err_hist: LogHistogram,
    /// Signed relative error sum ((predicted - actual) / actual); divided
    /// by `est_rel_err_hist.count()` this is the estimator's bias.
    pub est_signed_err_sum: f64,
}

/// Windowed ratio series from two cumulative counters sampled at the same
/// instants: d(hits)/d(lookups) per step, carrying the last value through
/// empty windows.
///
/// The two series are expected to be aligned (same sampling instants, same
/// length — debug builds assert the instants of the common prefix match).
/// When one series has extra trailing samples (a capture cut mid-window),
/// the tail is *not* dropped: each trailing instant gets the last computed
/// ratio, mirroring the empty-window carry behavior above.
pub fn windowed_ratio(lookups: &TimeSeries, hits: &TimeSeries) -> TimeSeries {
    let mut out = TimeSeries::default();
    let mut last = (0.0, 0.0);
    let mut last_ratio = 0.0;
    let n = lookups.points.len().min(hits.points.len());
    for (&(t, l), &(th, h)) in lookups.points[..n].iter().zip(&hits.points[..n]) {
        debug_assert!(
            (t - th).abs() < 1e-9,
            "windowed_ratio: misaligned sampling instants {t} vs {th}"
        );
        let dl = l - last.0;
        let dh = h - last.1;
        if dl > 0.0 {
            last_ratio = (dh / dl).clamp(0.0, 1.0);
        }
        out.push(t, last_ratio);
        last = (l, h);
    }
    let longer = if lookups.points.len() > n {
        &lookups.points[n..]
    } else {
        &hits.points[n..]
    };
    for &(t, _) in longer {
        out.push(t, last_ratio);
    }
    out
}

/// Sliding-window view over a *cumulative* [`LogHistogram`] (PR 9).
///
/// The PR 6 histograms are cumulative by design (cheap associative merge);
/// the SLO-guard feedback loop needs the last `W` seconds, not lifetime
/// history. `WindowedHist` keeps a ring of cumulative bucket-count
/// snapshots, one per `push` (the controller pushes once per sync
/// quantum), and answers window queries as the element-wise difference
/// between the newest snapshot and the newest snapshot at least `W`
/// seconds older. All slots are pre-sized at construction, so `push` and
/// every query are allocation-free — the controller tick can run inside
/// the coordinator phase without breaking the steady-state alloc
/// discipline.
///
/// Startup semantics: until a snapshot older than the window exists, the
/// baseline is the all-zero snapshot (the window covers "everything so
/// far"). Once the ring has wrapped, the oldest retained snapshot is used
/// as a best-effort baseline (it is at most one quantum older than `W`).
#[derive(Clone, Debug)]
pub struct WindowedHist {
    window: f64,
    /// Ring of cumulative snapshots, each `LogHistogram::BUCKETS` wide.
    slots: Vec<WindowSlot>,
    /// Next slot index to (over)write.
    head: usize,
    /// Number of valid slots (saturates at `slots.len()`).
    len: usize,
}

#[derive(Clone, Debug)]
struct WindowSlot {
    at: f64,
    counts: Vec<u64>,
    total: u64,
}

impl WindowedHist {
    /// `window` seconds of history, snapshotted every ~`dt` seconds. The
    /// ring holds `ceil(window/dt) + 2` slots so a baseline at least
    /// `window` old is always retained once warm.
    pub fn new(window: f64, dt: f64) -> Self {
        let cap = ((window / dt.max(1e-9)).ceil() as usize).saturating_add(2);
        let slots = (0..cap)
            .map(|_| WindowSlot {
                at: f64::NEG_INFINITY,
                counts: vec![0u64; LogHistogram::BUCKETS],
                total: 0,
            })
            .collect();
        WindowedHist {
            window,
            slots,
            head: 0,
            len: 0,
        }
    }

    pub fn window(&self) -> f64 {
        self.window
    }

    /// Record a cumulative snapshot at virtual time `at` (monotone
    /// non-decreasing across calls). `counts` is the histogram's raw
    /// bucket array — an empty slice (lazily unallocated histogram) is
    /// treated as all-zeros. Allocation-free.
    // lint: hot-path
    pub fn push(&mut self, at: f64, counts: &[u64]) {
        let slot = &mut self.slots[self.head];
        slot.at = at;
        let mut total = 0u64;
        for (i, dst) in slot.counts.iter_mut().enumerate() {
            let c = counts.get(i).copied().unwrap_or(0);
            *dst = c;
            total += c;
        }
        slot.total = total;
        self.head = (self.head + 1) % self.slots.len();
        self.len = (self.len + 1).min(self.slots.len());
    }

    /// Index of the newest slot (the last `push`), if any.
    fn newest(&self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        Some((self.head + self.slots.len() - 1) % self.slots.len())
    }

    /// Baseline slot for the current window: the newest retained snapshot
    /// at least `window` older than the newest one. `None` means the
    /// all-zero (startup) baseline.
    fn baseline(&self) -> Option<usize> {
        let newest = self.newest()?;
        let cutoff = self.slots[newest].at - self.window;
        let mut best: Option<usize> = None;
        for k in 1..self.len {
            let i = (self.head + self.slots.len() - 1 - k) % self.slots.len();
            if self.slots[i].at <= cutoff {
                best = match best {
                    Some(b) if self.slots[b].at >= self.slots[i].at => Some(b),
                    _ => Some(i),
                };
            }
        }
        if best.is_none() && self.len == self.slots.len() {
            // Ring wrapped: everything retained is younger than the
            // window cutoff should be impossible (capacity covers the
            // window), but fall back to the oldest slot for safety.
            return Some((self.head + self.slots.len() - self.len) % self.slots.len());
        }
        best
    }

    /// Samples recorded inside the window.
    pub fn count(&self) -> u64 {
        let Some(newest) = self.newest() else {
            return 0;
        };
        let base_total = self.baseline().map_or(0, |b| self.slots[b].total);
        self.slots[newest].total - base_total
    }

    /// Fraction of window samples at or below `threshold` (bucket
    /// resolution: the boundary bucket counts as attained, so the answer
    /// is within [`LogHistogram::REL_ERROR`] of exact). Empty windows are
    /// vacuously attained (1.0) — this is what lets a browned-out fleet
    /// with no fresh online traffic recover to Normal.
    pub fn attainment(&self, threshold: f64) -> f64 {
        let Some(newest) = self.newest() else {
            return 1.0;
        };
        let base = self.baseline();
        let cut = LogHistogram::bucket_index(threshold);
        let mut ok = 0u64;
        let mut n = 0u64;
        for i in 0..LogHistogram::BUCKETS {
            let b = base.map_or(0, |bi| self.slots[bi].counts[i]);
            let d = self.slots[newest].counts[i] - b;
            n += d;
            if i <= cut {
                ok += d;
            }
        }
        if n == 0 {
            1.0
        } else {
            ok as f64 / n as f64
        }
    }

    /// Percentile estimate over the window delta (p in [0, 100]); 0.0 for
    /// an empty window. Bucket-midpoint resolution, like
    /// [`LogHistogram::percentile`] but without the exact min/max clamp
    /// (the window does not track extremes).
    pub fn percentile(&self, p: f64) -> f64 {
        let Some(newest) = self.newest() else {
            return 0.0;
        };
        let base = self.baseline();
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let rank = rank.min(n);
        let mut cum = 0u64;
        for i in 0..LogHistogram::BUCKETS {
            let b = base.map_or(0, |bi| self.slots[bi].counts[i]);
            cum += self.slots[newest].counts[i] - b;
            if cum >= rank {
                return LogHistogram::bucket_value(i);
            }
        }
        LogHistogram::bucket_value(LogHistogram::BUCKETS - 1)
    }
}

/// Percentile snapshot of one streaming histogram: p50/p90/p99 are within
/// [`LogHistogram::REL_ERROR`] of the exact pooled percentiles; mean and
/// count are exact.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistStats {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl HistStats {
    pub fn of(h: &LogHistogram) -> HistStats {
        HistStats {
            count: h.count(),
            mean: h.mean(),
            p50: h.percentile(50.0),
            p90: h.percentile(90.0),
            p99: h.percentile(99.0),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count)
            .set("mean", self.mean)
            .set("p50", self.p50)
            .set("p90", self.p90)
            .set("p99", self.p99)
    }
}

/// Fleet-mergeable latency/accuracy digest: built per engine by
/// [`Metrics::latency_view`], or over the merged rollup for a cluster —
/// merging the underlying histograms first is what makes the cluster's
/// percentiles true pooled percentiles.
#[derive(Clone, Debug, Default)]
pub struct LatencyView {
    pub ttft: HistStats,
    pub tpot: HistStats,
    pub queue_wait: HistStats,
    /// |predicted - actual| / actual of the execution-time estimator.
    pub est_err: HistStats,
    /// Mean signed relative error (positive = over-prediction).
    pub est_bias: f64,
}

impl LatencyView {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ttft", self.ttft.to_json())
            .set("tpot", self.tpot.to_json())
            .set("queue_wait", self.queue_wait.to_json())
            .set(
                "estimator",
                self.est_err.to_json().set("bias", self.est_bias),
            )
    }
}

impl Metrics {
    /// Fold `other` into this rollup (cluster aggregation): counters add,
    /// per-request latency samples concatenate, busy time sums (so the
    /// aggregate's throughputs are per-GPU-busy-second across the fleet).
    /// Time series are deliberately left untouched — they are per-engine
    /// views over one virtual clock; the cluster keeps its own timeline.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.online_ttft.extend_from_slice(&other.online_ttft);
        self.online_tpot.extend_from_slice(&other.online_tpot);
        self.online_completed += other.online_completed;
        self.offline_completed += other.offline_completed;
        self.online_tokens_out += other.online_tokens_out;
        self.offline_tokens_out += other.offline_tokens_out;
        self.offline_billed_tokens += other.offline_billed_tokens;
        self.prefill_tokens_computed += other.prefill_tokens_computed;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.online_tokens_checked += other.online_tokens_checked;
        self.online_token_deadlines_met += other.online_token_deadlines_met;
        self.iterations += other.iterations;
        self.busy_time += other.busy_time;
        self.preemptions += other.preemptions;
        self.skipped_offline += other.skipped_offline;
        self.cancelled_online += other.cancelled_online;
        self.cancelled_offline += other.cancelled_offline;
        self.exec_faults += other.exec_faults;
        self.exec_retries += other.exec_retries;
        self.ttft_hist.merge_from(&other.ttft_hist);
        self.tpot_hist.merge_from(&other.tpot_hist);
        self.queue_wait_hist.merge_from(&other.queue_wait_hist);
        self.est_rel_err_hist.merge_from(&other.est_rel_err_hist);
        self.est_signed_err_sum += other.est_signed_err_sum;
    }

    /// Aggregate rollup over per-replica metrics (cluster reporting).
    pub fn aggregate<'a>(parts: impl IntoIterator<Item = &'a Metrics>) -> Metrics {
        let mut m = Metrics::default();
        for p in parts {
            m.merge_from(p);
        }
        m
    }

    pub fn record_completion(
        &mut self,
        class: TaskClass,
        tokens_out: usize,
        prompt_len: usize,
        ttft: Option<f64>,
        tpot: Option<f64>,
    ) {
        match class {
            TaskClass::Online => {
                self.online_completed += 1;
                self.online_tokens_out += tokens_out as u64;
                if let Some(t) = ttft {
                    self.online_ttft.push(t);
                    self.ttft_hist.record(t);
                }
                if let Some(t) = tpot {
                    self.online_tpot.push(t);
                    self.tpot_hist.record(t);
                }
            }
            TaskClass::Offline => {
                self.offline_completed += 1;
                self.offline_tokens_out += tokens_out as u64;
                self.offline_billed_tokens += (prompt_len + tokens_out) as u64;
            }
        }
    }

    /// Count a client-side cancellation (terminal, no completion).
    pub fn record_cancellation(&mut self, class: TaskClass) {
        match class {
            TaskClass::Online => self.cancelled_online += 1,
            TaskClass::Offline => self.cancelled_offline += 1,
        }
    }

    /// Offline throughput = billed tokens (prompt + output) of completed
    /// offline requests per second of busy time — the quantity Fig. 6
    /// compares across strategies (the batch API charges per processed
    /// token, and the paper's benefit counts processed tokens).
    pub fn offline_throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.offline_billed_tokens as f64 / self.busy_time
        }
    }

    /// Output-only offline throughput (secondary view).
    pub fn offline_output_throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.offline_tokens_out as f64 / self.busy_time
        }
    }

    pub fn online_throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.online_tokens_out as f64 / self.busy_time
        }
    }

    /// One executed iteration's estimator audit sample: `est` was the
    /// scheduler's predicted batch time (Eq. 8), `actual` what the backend
    /// reported. No-ops when the estimator made no prediction.
    pub fn record_estimate(&mut self, est: f64, actual: f64) {
        if est <= 0.0 || actual <= 0.0 {
            return;
        }
        let rel = (est - actual) / actual;
        self.est_rel_err_hist.record(rel.abs());
        self.est_signed_err_sum += rel;
    }

    /// Mean signed relative error of the estimator ((est - actual)/actual);
    /// positive = the time model over-predicts.
    pub fn estimator_bias(&self) -> f64 {
        let n = self.est_rel_err_hist.count();
        if n == 0 {
            0.0
        } else {
            self.est_signed_err_sum / n as f64
        }
    }

    /// Mergeable percentile digest for [`crate::serve::MetricsView`] and
    /// the wire `metrics` reply.
    pub fn latency_view(&self) -> LatencyView {
        LatencyView {
            ttft: HistStats::of(&self.ttft_hist),
            tpot: HistStats::of(&self.tpot_hist),
            queue_wait: HistStats::of(&self.queue_wait_hist),
            est_err: HistStats::of(&self.est_rel_err_hist),
            est_bias: self.estimator_bias(),
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.online_ttft)
    }

    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.online_tpot)
    }

    /// (TTFT attainment, per-token deadline attainment) against an SLO.
    /// The token measure follows §5.1's cumulative deadline form, which is
    /// what the scheduler enforces; distribution summaries of raw TTFT/TPOT
    /// remain available for Fig. 7.
    pub fn slo_attainment(&self, slo: &crate::core::Slo) -> (f64, f64) {
        let token = if self.online_tokens_checked == 0 {
            1.0
        } else {
            self.online_token_deadlines_met as f64 / self.online_tokens_checked as f64
        };
        (Summary::attainment(&self.online_ttft, slo.ttft), token)
    }

    pub fn to_json(&self, slo: &crate::core::Slo) -> Json {
        let ttft = self.ttft_summary();
        let tpot = self.tpot_summary();
        let (a_ttft, a_tpot) = self.slo_attainment(slo);
        Json::obj()
            .set("iterations", self.iterations)
            .set("busy_time", self.busy_time)
            .set("online_completed", self.online_completed)
            .set("offline_completed", self.offline_completed)
            .set("online_tokens_out", self.online_tokens_out)
            .set("offline_tokens_out", self.offline_tokens_out)
            .set("offline_billed_tokens", self.offline_billed_tokens)
            .set("offline_throughput_tok_s", self.offline_throughput())
            .set("offline_output_throughput_tok_s", self.offline_output_throughput())
            .set("online_throughput_tok_s", self.online_throughput())
            .set("prefill_tokens_computed", self.prefill_tokens_computed)
            .set("prefill_tokens_saved", self.prefill_tokens_saved)
            .set("preemptions", self.preemptions)
            .set("skipped_offline", self.skipped_offline)
            .set("cancelled_online", self.cancelled_online)
            .set("cancelled_offline", self.cancelled_offline)
            .set("exec_faults", self.exec_faults)
            .set("exec_retries", self.exec_retries)
            .set(
                "ttft",
                Json::obj()
                    .set("p50", ttft.p50)
                    .set("p90", ttft.p90)
                    .set("p99", ttft.p99)
                    .set("mean", ttft.mean)
                    .set("attainment", a_ttft),
            )
            .set(
                "tpot",
                Json::obj()
                    .set("p50", tpot.p50)
                    .set("p90", tpot.p90)
                    .set("p99", tpot.p99)
                    .set("mean", tpot.mean)
                    .set("attainment", a_tpot),
            )
            .set("latency", self.latency_view().to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Slo;

    #[test]
    fn completion_accounting() {
        let mut m = Metrics::default();
        m.busy_time = 10.0;
        m.record_completion(TaskClass::Offline, 100, 400, None, None);
        m.record_completion(TaskClass::Online, 20, 50, Some(0.5), Some(0.04));
        assert_eq!(m.offline_completed, 1);
        assert_eq!(m.online_completed, 1);
        assert!((m.offline_throughput() - 50.0).abs() < 1e-12);
        assert!((m.offline_output_throughput() - 10.0).abs() < 1e-12);
        let (a_ttft, a_tpot) = m.slo_attainment(&Slo::paper_eval());
        assert_eq!(a_ttft, 1.0);
        assert_eq!(a_tpot, 1.0);
    }

    #[test]
    fn sample_ctl_rate_limits() {
        let mut s = SampleCtl::new(1.0);
        assert!(s.due(0.0));
        assert!(!s.due(0.5));
        assert!(s.due(1.01));
    }

    #[test]
    fn json_export_parses() {
        let m = Metrics::default();
        let j = m.to_json(&Slo::paper_eval());
        assert!(j.at("ttft.attainment").is_some());
    }

    #[test]
    fn aggregate_rolls_up_counters_and_samples() {
        let mut a = Metrics::default();
        a.busy_time = 5.0;
        a.record_completion(TaskClass::Online, 10, 100, Some(0.4), Some(0.03));
        a.record_completion(TaskClass::Offline, 50, 500, None, None);
        let mut b = Metrics::default();
        b.busy_time = 3.0;
        b.record_completion(TaskClass::Online, 20, 200, Some(1.4), Some(0.06));
        let agg = Metrics::aggregate([&a, &b]);
        assert_eq!(agg.online_completed, 2);
        assert_eq!(agg.offline_completed, 1);
        assert_eq!(agg.online_tokens_out, 30);
        assert_eq!(agg.offline_billed_tokens, 550);
        assert_eq!(agg.online_ttft.len(), 2);
        assert!((agg.busy_time - 8.0).abs() < 1e-12);
        // Attainment over the pooled samples: one of two TTFTs meets 1.0 s.
        let (a_ttft, _) = agg.slo_attainment(&Slo::paper_eval());
        assert!((a_ttft - 0.5).abs() < 1e-12);
    }

    #[test]
    fn windowed_ratio_carries_tail_instead_of_truncating() {
        // Regression: `zip` used to drop trailing samples of the longer
        // series; the tail must carry the last computed ratio instead.
        let mut lookups = TimeSeries::default();
        let mut hits = TimeSeries::default();
        lookups.push(0.0, 10.0);
        hits.push(0.0, 5.0);
        lookups.push(1.0, 20.0);
        hits.push(1.0, 10.0);
        lookups.push(2.0, 40.0); // capture cut mid-window: no hits sample
        let r = windowed_ratio(&lookups, &hits);
        assert_eq!(r.points.len(), 3);
        assert!((r.points[1].1 - 0.5).abs() < 1e-12);
        assert_eq!(r.points[2], (2.0, 0.5));
        // Symmetric case: hits longer than lookups.
        let mut hits2 = hits.clone();
        hits2.push(2.0, 12.0);
        hits2.push(3.0, 13.0);
        let mut lookups2 = TimeSeries::default();
        lookups2.push(0.0, 10.0);
        lookups2.push(1.0, 20.0);
        let r2 = windowed_ratio(&lookups2, &hits2);
        assert_eq!(r2.points.len(), 4);
        assert_eq!(r2.points[2], (2.0, 0.5));
        assert_eq!(r2.points[3], (3.0, 0.5));
    }

    #[test]
    fn sample_ctl_reset_preserves_cadence() {
        let mut s = SampleCtl::new(1.0);
        assert!(s.due(0.0));
        assert_eq!(s.last_sample(), 0.0);
        // Re-anchoring at the previous sample instant keeps the next sample
        // due at anchor + min_interval, not "immediately".
        let anchor = s.last_sample();
        let mut s2 = SampleCtl::new(1.0);
        s2.reset(anchor);
        assert!(!s2.due(0.5));
        assert!(s2.due(1.0));
    }

    #[test]
    fn estimator_audit_records_relative_error_and_bias() {
        let mut m = Metrics::default();
        m.record_estimate(1.2, 1.0); // +20%
        m.record_estimate(0.9, 1.0); // -10%
        m.record_estimate(0.0, 1.0); // ignored: no prediction
        m.record_estimate(1.0, 0.0); // ignored: no actual
        assert_eq!(m.est_rel_err_hist.count(), 2);
        assert!((m.estimator_bias() - 0.05).abs() < 1e-12);
        let v = m.latency_view();
        assert_eq!(v.est_err.count, 2);
        assert!((v.est_err.mean - 0.15).abs() < 1e-12);
    }

    #[test]
    fn histograms_merge_through_aggregate() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        for i in 0..50 {
            a.record_completion(
                TaskClass::Online,
                10,
                100,
                Some(0.1 + i as f64 * 0.01),
                Some(0.03),
            );
            b.record_completion(
                TaskClass::Online,
                10,
                100,
                Some(1.0 + i as f64 * 0.01),
                Some(0.05),
            );
        }
        a.record_estimate(1.1, 1.0);
        b.record_estimate(0.8, 1.0);
        let agg = Metrics::aggregate([&a, &b]);
        assert_eq!(agg.ttft_hist.count(), 100);
        assert_eq!(agg.tpot_hist.count(), 100);
        assert_eq!(agg.est_rel_err_hist.count(), 2);
        // Pooled p50 sits between the two replicas' medians.
        let p50 = agg.ttft_hist.percentile(50.0);
        assert!(p50 > a.ttft_hist.percentile(90.0) * 0.9);
        assert!(p50 < b.ttft_hist.percentile(10.0) * 1.1);
        // Bias averages over the pooled sample count.
        assert!((agg.estimator_bias() - (-0.05)).abs() < 1e-12);
    }

    #[test]
    fn windowed_hist_sees_only_the_last_window() {
        // Cumulative histogram: 100 fast samples, then 100 slow ones. A
        // window that covers only the slow phase must report the slow
        // percentile and the slow-phase attainment, not lifetime history.
        let mut h = LogHistogram::default();
        let mut w = WindowedHist::new(10.0, 1.0);
        let mut t = 0.0;
        for step in 0..40 {
            for _ in 0..5 {
                h.record(if step < 20 { 0.1 } else { 2.0 });
            }
            t += 1.0;
            w.push(t, h.bucket_counts());
        }
        // Window [30, 40]: slow samples only.
        assert_eq!(w.count(), 50);
        let p50 = w.percentile(50.0);
        assert!((p50 / 2.0 - 1.0).abs() < 0.05, "p50 {p50}");
        assert!(w.attainment(1.0) < 1e-9);
        assert!((w.attainment(3.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn windowed_hist_startup_and_empty_semantics() {
        let mut w = WindowedHist::new(10.0, 1.0);
        // No snapshots at all: vacuous attainment, zero percentile.
        assert_eq!(w.count(), 0);
        assert_eq!(w.attainment(1.0), 1.0);
        assert_eq!(w.percentile(99.0), 0.0);
        // Startup (no baseline older than the window): everything counts.
        let mut h = LogHistogram::default();
        h.record(0.5);
        w.push(1.0, h.bucket_counts());
        assert_eq!(w.count(), 1);
        assert!((w.attainment(1.0) - 1.0).abs() < 1e-12);
        // A quiet stretch longer than the window empties it again.
        let mut t = 1.0;
        for _ in 0..15 {
            t += 1.0;
            w.push(t, h.bucket_counts());
        }
        assert_eq!(w.count(), 0, "stale samples must age out");
        assert_eq!(w.attainment(0.001), 1.0, "empty window is vacuously attained");
    }

    #[test]
    fn windowed_hist_tolerates_lazy_empty_counts() {
        // A defaulted LogHistogram has no bucket vector; the window must
        // treat the empty slice as all-zeros.
        let h = LogHistogram::default();
        let mut w = WindowedHist::new(5.0, 1.0);
        w.push(1.0, h.bucket_counts());
        w.push(2.0, h.bucket_counts());
        assert_eq!(w.count(), 0);
        assert_eq!(w.attainment(1.0), 1.0);
    }

    #[test]
    fn latency_view_exports_json_percentiles() {
        let mut m = Metrics::default();
        for i in 0..100 {
            m.record_completion(
                TaskClass::Online,
                5,
                50,
                Some(0.2 + i as f64 * 0.002),
                Some(0.04),
            );
        }
        m.queue_wait_hist.record(0.5);
        m.record_estimate(1.05, 1.0);
        let j = m.to_json(&Slo::paper_eval());
        for key in [
            "latency.ttft.p50",
            "latency.ttft.p99",
            "latency.tpot.p90",
            "latency.queue_wait.count",
            "latency.estimator.mean",
            "latency.estimator.bias",
        ] {
            assert!(j.at(key).is_some(), "missing {key}");
        }
        assert_eq!(j.at("latency.ttft.count").unwrap().as_u64(), Some(100));
        let p50 = j.at("latency.ttft.p50").unwrap().as_f64().unwrap();
        assert!((p50 / 0.3 - 1.0).abs() < 0.06, "p50 {p50} far from 0.3");
    }
}

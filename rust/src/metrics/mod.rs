//! Serving metrics: per-request latency (TTFT/TPOT), throughput, SLO
//! attainment, and the time series behind Figures 8-11 (active requests,
//! memory breakdown, prefix-cache hit ratio, predictor traces).

use crate::core::TaskClass;
use crate::utils::json::Json;
use crate::utils::stats::{Summary, TimeSeries};

/// Snapshot cadence control: long simulations sample series sparsely.
#[derive(Clone, Copy, Debug)]
pub struct SampleCtl {
    min_interval: f64,
    last: f64,
}

impl SampleCtl {
    pub fn new(min_interval: f64) -> Self {
        SampleCtl {
            min_interval,
            last: f64::NEG_INFINITY,
        }
    }

    pub fn due(&mut self, t: f64) -> bool {
        if t - self.last >= self.min_interval {
            self.last = t;
            true
        } else {
            false
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    // ---- per-request latency (online) ----
    pub online_ttft: Vec<f64>,
    pub online_tpot: Vec<f64>,
    // ---- completions & token counts ----
    pub online_completed: usize,
    pub offline_completed: usize,
    pub online_tokens_out: u64,
    pub offline_tokens_out: u64,
    /// Billed tokens (prompt + output) of completed offline requests — the
    /// batch-API work unit behind the paper's offline-throughput metric
    /// (benefit = tokens processed, Eq. 1; a cache-hit prefix still counts:
    /// the request's tokens were served, just without recompute).
    pub offline_billed_tokens: u64,
    /// Prefill tokens actually computed (recompute shows up here).
    pub prefill_tokens_computed: u64,
    /// Prefill tokens skipped via prefix-cache fast-forward.
    pub prefill_tokens_saved: u64,
    // ---- per-token SLO attainment (paper §5.1: token i's deadline is
    // arrival + TTFT + i·TPOT; a token is attained if it lands by then) ----
    pub online_tokens_checked: u64,
    pub online_token_deadlines_met: u64,
    // ---- engine counters ----
    pub iterations: usize,
    pub busy_time: f64,
    pub preemptions: usize,
    pub skipped_offline: usize,
    /// Requests withdrawn through the serving API before completion
    /// (dropped clients, explicit `cancel` verbs, harvested offline work).
    pub cancelled_online: usize,
    pub cancelled_offline: usize,
    // ---- time series (Figures 8-10) ----
    pub active_online: TimeSeries,
    pub active_offline: TimeSeries,
    pub mem_running: TimeSeries,
    pub mem_cached_online: TimeSeries,
    pub mem_cached_offline: TimeSeries,
    pub mem_free: TimeSeries,
    pub hit_ratio: TimeSeries,
    /// Cumulative prefix-lookup / hit block counts (windowed ratios for
    /// Fig. 9 are differenced from these).
    pub cache_lookups_cum: TimeSeries,
    pub cache_hits_cum: TimeSeries,
    pub online_arrivals: TimeSeries,
}

/// Windowed ratio series from two cumulative counters sampled at the same
/// instants: d(hits)/d(lookups) per step, carrying the last value through
/// empty windows.
pub fn windowed_ratio(lookups: &TimeSeries, hits: &TimeSeries) -> TimeSeries {
    let mut out = TimeSeries::default();
    let mut last = (0.0, 0.0);
    let mut last_ratio = 0.0;
    for (&(t, l), &(_, h)) in lookups.points.iter().zip(&hits.points) {
        let dl = l - last.0;
        let dh = h - last.1;
        if dl > 0.0 {
            last_ratio = (dh / dl).clamp(0.0, 1.0);
        }
        out.push(t, last_ratio);
        last = (l, h);
    }
    out
}

impl Metrics {
    /// Fold `other` into this rollup (cluster aggregation): counters add,
    /// per-request latency samples concatenate, busy time sums (so the
    /// aggregate's throughputs are per-GPU-busy-second across the fleet).
    /// Time series are deliberately left untouched — they are per-engine
    /// views over one virtual clock; the cluster keeps its own timeline.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.online_ttft.extend_from_slice(&other.online_ttft);
        self.online_tpot.extend_from_slice(&other.online_tpot);
        self.online_completed += other.online_completed;
        self.offline_completed += other.offline_completed;
        self.online_tokens_out += other.online_tokens_out;
        self.offline_tokens_out += other.offline_tokens_out;
        self.offline_billed_tokens += other.offline_billed_tokens;
        self.prefill_tokens_computed += other.prefill_tokens_computed;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.online_tokens_checked += other.online_tokens_checked;
        self.online_token_deadlines_met += other.online_token_deadlines_met;
        self.iterations += other.iterations;
        self.busy_time += other.busy_time;
        self.preemptions += other.preemptions;
        self.skipped_offline += other.skipped_offline;
        self.cancelled_online += other.cancelled_online;
        self.cancelled_offline += other.cancelled_offline;
    }

    /// Aggregate rollup over per-replica metrics (cluster reporting).
    pub fn aggregate<'a>(parts: impl IntoIterator<Item = &'a Metrics>) -> Metrics {
        let mut m = Metrics::default();
        for p in parts {
            m.merge_from(p);
        }
        m
    }

    pub fn record_completion(
        &mut self,
        class: TaskClass,
        tokens_out: usize,
        prompt_len: usize,
        ttft: Option<f64>,
        tpot: Option<f64>,
    ) {
        match class {
            TaskClass::Online => {
                self.online_completed += 1;
                self.online_tokens_out += tokens_out as u64;
                if let Some(t) = ttft {
                    self.online_ttft.push(t);
                }
                if let Some(t) = tpot {
                    self.online_tpot.push(t);
                }
            }
            TaskClass::Offline => {
                self.offline_completed += 1;
                self.offline_tokens_out += tokens_out as u64;
                self.offline_billed_tokens += (prompt_len + tokens_out) as u64;
            }
        }
    }

    /// Count a client-side cancellation (terminal, no completion).
    pub fn record_cancellation(&mut self, class: TaskClass) {
        match class {
            TaskClass::Online => self.cancelled_online += 1,
            TaskClass::Offline => self.cancelled_offline += 1,
        }
    }

    /// Offline throughput = billed tokens (prompt + output) of completed
    /// offline requests per second of busy time — the quantity Fig. 6
    /// compares across strategies (the batch API charges per processed
    /// token, and the paper's benefit counts processed tokens).
    pub fn offline_throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.offline_billed_tokens as f64 / self.busy_time
        }
    }

    /// Output-only offline throughput (secondary view).
    pub fn offline_output_throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.offline_tokens_out as f64 / self.busy_time
        }
    }

    pub fn online_throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.online_tokens_out as f64 / self.busy_time
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.online_ttft)
    }

    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.online_tpot)
    }

    /// (TTFT attainment, per-token deadline attainment) against an SLO.
    /// The token measure follows §5.1's cumulative deadline form, which is
    /// what the scheduler enforces; distribution summaries of raw TTFT/TPOT
    /// remain available for Fig. 7.
    pub fn slo_attainment(&self, slo: &crate::core::Slo) -> (f64, f64) {
        let token = if self.online_tokens_checked == 0 {
            1.0
        } else {
            self.online_token_deadlines_met as f64 / self.online_tokens_checked as f64
        };
        (Summary::attainment(&self.online_ttft, slo.ttft), token)
    }

    pub fn to_json(&self, slo: &crate::core::Slo) -> Json {
        let ttft = self.ttft_summary();
        let tpot = self.tpot_summary();
        let (a_ttft, a_tpot) = self.slo_attainment(slo);
        Json::obj()
            .set("iterations", self.iterations)
            .set("busy_time", self.busy_time)
            .set("online_completed", self.online_completed)
            .set("offline_completed", self.offline_completed)
            .set("online_tokens_out", self.online_tokens_out)
            .set("offline_tokens_out", self.offline_tokens_out)
            .set("offline_billed_tokens", self.offline_billed_tokens)
            .set("offline_throughput_tok_s", self.offline_throughput())
            .set("offline_output_throughput_tok_s", self.offline_output_throughput())
            .set("online_throughput_tok_s", self.online_throughput())
            .set("prefill_tokens_computed", self.prefill_tokens_computed)
            .set("prefill_tokens_saved", self.prefill_tokens_saved)
            .set("preemptions", self.preemptions)
            .set("skipped_offline", self.skipped_offline)
            .set("cancelled_online", self.cancelled_online)
            .set("cancelled_offline", self.cancelled_offline)
            .set(
                "ttft",
                Json::obj()
                    .set("p50", ttft.p50)
                    .set("p90", ttft.p90)
                    .set("p99", ttft.p99)
                    .set("mean", ttft.mean)
                    .set("attainment", a_ttft),
            )
            .set(
                "tpot",
                Json::obj()
                    .set("p50", tpot.p50)
                    .set("p90", tpot.p90)
                    .set("p99", tpot.p99)
                    .set("mean", tpot.mean)
                    .set("attainment", a_tpot),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Slo;

    #[test]
    fn completion_accounting() {
        let mut m = Metrics::default();
        m.busy_time = 10.0;
        m.record_completion(TaskClass::Offline, 100, 400, None, None);
        m.record_completion(TaskClass::Online, 20, 50, Some(0.5), Some(0.04));
        assert_eq!(m.offline_completed, 1);
        assert_eq!(m.online_completed, 1);
        assert!((m.offline_throughput() - 50.0).abs() < 1e-12);
        assert!((m.offline_output_throughput() - 10.0).abs() < 1e-12);
        let (a_ttft, a_tpot) = m.slo_attainment(&Slo::paper_eval());
        assert_eq!(a_ttft, 1.0);
        assert_eq!(a_tpot, 1.0);
    }

    #[test]
    fn sample_ctl_rate_limits() {
        let mut s = SampleCtl::new(1.0);
        assert!(s.due(0.0));
        assert!(!s.due(0.5));
        assert!(s.due(1.01));
    }

    #[test]
    fn json_export_parses() {
        let m = Metrics::default();
        let j = m.to_json(&Slo::paper_eval());
        assert!(j.at("ttft.attainment").is_some());
    }

    #[test]
    fn aggregate_rolls_up_counters_and_samples() {
        let mut a = Metrics::default();
        a.busy_time = 5.0;
        a.record_completion(TaskClass::Online, 10, 100, Some(0.4), Some(0.03));
        a.record_completion(TaskClass::Offline, 50, 500, None, None);
        let mut b = Metrics::default();
        b.busy_time = 3.0;
        b.record_completion(TaskClass::Online, 20, 200, Some(1.4), Some(0.06));
        let agg = Metrics::aggregate([&a, &b]);
        assert_eq!(agg.online_completed, 2);
        assert_eq!(agg.offline_completed, 1);
        assert_eq!(agg.online_tokens_out, 30);
        assert_eq!(agg.offline_billed_tokens, 550);
        assert_eq!(agg.online_ttft.len(), 2);
        assert!((agg.busy_time - 8.0).abs() < 1e-12);
        // Attainment over the pooled samples: one of two TTFTs meets 1.0 s.
        let (a_ttft, _) = agg.slo_attainment(&Slo::paper_eval());
        assert!((a_ttft - 0.5).abs() < 1e-12);
    }
}

//! System configuration: model geometry, KV capacity, SLOs, scheduler and
//! cache policy knobs, execution-time-model coefficients. Loadable from a
//! JSON file, overridable from the CLI, with two presets:
//!
//!   * `a100_llama8b()` — the paper's evaluation testbed (A100-40G,
//!     LLaMA-3.1-8B), used by the cost-model backend for Figures 6-11;
//!   * `cpu_echolm()`   — the real-execution testbed (CPU PJRT + EchoLM
//!     artifacts), used by the end-to-end examples.

use crate::core::Slo;
use crate::utils::json::Json;
use anyhow::{anyhow, Context, Result};

/// Which of the paper's four strategies (§7.1 "Baselines") drives the
/// scheduler. Each adds one Echo component on top of the previous:
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// BS — vLLM + priority scheduling: online preempts offline, FCFS
    /// offline admission, no SLO estimation.
    Bs,
    /// BS+E — adds the execution-time estimator: offline admission is
    /// SLO-constrained.
    BsE,
    /// BS+E+S — adds the KV-cache-aware offline selection (plan
    /// generator/selector).
    BsES,
    /// BS+E+S+M — full Echo: adds the task-aware KV cache manager
    /// (priority eviction + threshold).
    Echo,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "bs" => SchedulerKind::Bs,
            "bs+e" | "bse" => SchedulerKind::BsE,
            "bs+e+s" | "bses" => SchedulerKind::BsES,
            "echo" | "bs+e+s+m" => SchedulerKind::Echo,
            other => return Err(anyhow!("unknown scheduler kind {other:?}")),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Bs => "BS",
            SchedulerKind::BsE => "BS+E",
            SchedulerKind::BsES => "BS+E+S",
            SchedulerKind::Echo => "Echo",
        }
    }

    /// Components enabled by this strategy.
    pub fn uses_estimator(self) -> bool {
        !matches!(self, SchedulerKind::Bs)
    }

    pub fn uses_kv_aware_selection(self) -> bool {
        matches!(self, SchedulerKind::BsES | SchedulerKind::Echo)
    }

    pub fn uses_task_aware_cache(self) -> bool {
        matches!(self, SchedulerKind::Echo)
    }

    pub fn all() -> [SchedulerKind; 4] {
        [
            SchedulerKind::Bs,
            SchedulerKind::BsE,
            SchedulerKind::BsES,
            SchedulerKind::Echo,
        ]
    }
}

/// Model geometry — only what sizing/cost decisions need.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Bytes per cached element (2 = fp16 on GPU, 4 = f32 on our CPU path).
    pub kv_dtype_bytes: usize,
}

impl ModelSpec {
    /// KV bytes per token position (both K and V, all layers).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim * self.kv_dtype_bytes
    }
}

/// Scheduler knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    pub kind: SchedulerKind,
    /// Max requests per iteration batch (engine slots).
    pub max_batch: usize,
    /// Max total tokens (prefill chunks + decodes) per iteration.
    pub max_batched_tokens: usize,
    /// Prefill chunk width (chunked prefill, §2.1).
    pub chunk: usize,
    /// Echo plan generator: max candidate mutations evaluated per iteration
    /// (the "last batch ± small adjustments" search budget, §4.1).
    pub mutation_budget: usize,
    /// Prefix-cache hits fast-forward `computed` (skip recomputation).
    /// True for the simulated/paged substrate; false for the dense-slab
    /// PJRT path where a logical hit still needs physical recompute.
    pub fast_forward: bool,
}

/// KV cache knobs.
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Tokens per block (vLLM default 16).
    pub block_size: usize,
    /// Total KV capacity in tokens (N_KV of Eq. 5).
    pub capacity_tokens: usize,
    /// Task-aware priority eviction (§4.2) vs plain LRU.
    pub task_aware: bool,
    /// Reserve headroom for bursty online tasks (the threshold of §4.2),
    /// sized by the memory predictor.
    pub threshold: bool,
    /// Floor/initial reserve as a fraction of capacity until the predictor
    /// has history.
    pub reserve_frac: f64,
}

/// Execution-time model coefficients (Eqs. 6-8). Units: seconds, tokens.
#[derive(Clone, Copy, Debug)]
pub struct TimeModelConfig {
    pub alpha: f64,
    pub beta: f64,
    pub c: f64,
    pub gamma: f64,
    pub delta: f64,
    pub lambda: f64,
}

/// Memory predictor knobs (§5.3).
#[derive(Clone, Copy, Debug)]
pub struct PredictorConfig {
    /// Trailing history horizon, seconds (paper: an hour).
    pub history_horizon: f64,
    /// Prediction re-evaluation period, seconds (paper: minutes).
    pub update_period: f64,
    /// σ multiplier (paper: 2 ≈ 95% coverage).
    pub k_sigma: f64,
}

#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub model: ModelSpec,
    pub slo: Slo,
    pub scheduler: SchedulerConfig,
    pub cache: CacheConfig,
    pub time_model: TimeModelConfig,
    pub predictor: PredictorConfig,
    pub seed: u64,
}

impl SystemConfig {
    /// Paper testbed preset: A100-40G serving LLaMA-3.1-8B under vLLM.
    ///
    /// KV capacity: the 8B model (32 layers, 8 KV heads, dim 128, fp16)
    /// costs 128 KiB/token; ~13 GB of the 40 GB card is KV-usable after
    /// weights/activations => ~100k tokens. Time-model coefficients are
    /// calibrated to public A100 serving measurements (prefill ~1 s at 8k
    /// tokens compute-bound; decode iteration tens of ms memory-bound) and
    /// are *re-fitted* by `echo calibrate` against any backend.
    pub fn a100_llama8b() -> SystemConfig {
        SystemConfig {
            model: ModelSpec {
                name: "llama-3.1-8b".into(),
                n_layers: 32,
                n_kv_heads: 8,
                head_dim: 128,
                kv_dtype_bytes: 2,
            },
            slo: Slo::paper_eval(),
            scheduler: SchedulerConfig {
                kind: SchedulerKind::Echo,
                max_batch: 64,
                max_batched_tokens: 2048,
                chunk: 512,
                mutation_budget: 64,
                fast_forward: true,
            },
            cache: CacheConfig {
                block_size: 16,
                capacity_tokens: 100_000,
                task_aware: true,
                threshold: true,
                reserve_frac: 0.10,
            },
            // Calibrated to A100-40G + LLaMA-8B public measurements:
            //   prefill — compute-bound: 2·8e9 FLOP/token at ~55% of 312
            //   TFLOPs bf16 → β ≈ 6e-5 s/token; attention quadratic
            //   2·2·l²·d_kv/peak → α ≈ 4e-9; launch floor c ≈ 6 ms.
            //   decode — memory-bound: per-request KV read 131 kB/token of
            //   context at ~1.6 TB/s → δ ≈ 5e-6 s per mean-context token
            //   (Eq. 7 uses mean, not sum); γ ≈ 2e-6 for the longest-chain
            //   term. Sanity: 8k prefill ≈ 0.74 s; 64×500 decode ≈ 6 ms.
            time_model: TimeModelConfig {
                alpha: 4.0e-9,
                beta: 6.0e-5,
                c: 6e-3,
                gamma: 2.0e-6,
                delta: 5.0e-6,
                lambda: 0.85,
            },
            predictor: PredictorConfig {
                history_horizon: 3600.0,
                update_period: 60.0,
                k_sigma: 2.0,
            },
            seed: 42,
        }
    }

    /// Real-execution preset matching the EchoLM artifacts (CPU PJRT).
    /// Geometry fields are overwritten from artifacts/manifest.json by the
    /// runtime loader; time-model coefficients come from `echo calibrate`.
    pub fn cpu_echolm() -> SystemConfig {
        SystemConfig {
            model: ModelSpec {
                name: "echolm".into(),
                n_layers: 4,
                n_kv_heads: 4,
                head_dim: 32,
                kv_dtype_bytes: 4,
            },
            slo: Slo {
                ttft: 2.0,
                tpot: 0.5,
            },
            scheduler: SchedulerConfig {
                kind: SchedulerKind::Echo,
                max_batch: 8,
                max_batched_tokens: 256,
                chunk: 64,
                mutation_budget: 32,
                fast_forward: false,
            },
            cache: CacheConfig {
                block_size: 16,
                // 8 slots x 256 positions of the device slab.
                capacity_tokens: 2048,
                task_aware: true,
                threshold: true,
                reserve_frac: 0.15,
            },
            time_model: TimeModelConfig {
                alpha: 2e-7,
                beta: 4e-4,
                c: 3e-3,
                gamma: 1e-4,
                delta: 6e-4,
                lambda: 0.8,
            },
            predictor: PredictorConfig {
                history_horizon: 120.0,
                update_period: 5.0,
                k_sigma: 2.0,
            },
            seed: 42,
        }
    }

    pub fn preset(name: &str) -> Result<SystemConfig> {
        match name {
            "a100_llama8b" | "a100" | "paper" => Ok(Self::a100_llama8b()),
            "cpu_echolm" | "cpu" | "echolm" => Ok(Self::cpu_echolm()),
            other => Err(anyhow!(
                "unknown preset {other:?} (try a100_llama8b or cpu_echolm)"
            )),
        }
    }

    /// KV capacity in blocks.
    pub fn capacity_blocks(&self) -> usize {
        self.cache.capacity_tokens / self.cache.block_size
    }

    // ---- JSON round trip ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set(
                "model",
                Json::obj()
                    .set("name", self.model.name.as_str())
                    .set("n_layers", self.model.n_layers)
                    .set("n_kv_heads", self.model.n_kv_heads)
                    .set("head_dim", self.model.head_dim)
                    .set("kv_dtype_bytes", self.model.kv_dtype_bytes),
            )
            .set(
                "slo",
                Json::obj().set("ttft", self.slo.ttft).set("tpot", self.slo.tpot),
            )
            .set(
                "scheduler",
                Json::obj()
                    .set("kind", self.scheduler.kind.name())
                    .set("max_batch", self.scheduler.max_batch)
                    .set("max_batched_tokens", self.scheduler.max_batched_tokens)
                    .set("chunk", self.scheduler.chunk)
                    .set("mutation_budget", self.scheduler.mutation_budget)
                    .set("fast_forward", self.scheduler.fast_forward),
            )
            .set(
                "cache",
                Json::obj()
                    .set("block_size", self.cache.block_size)
                    .set("capacity_tokens", self.cache.capacity_tokens)
                    .set("task_aware", self.cache.task_aware)
                    .set("threshold", self.cache.threshold)
                    .set("reserve_frac", self.cache.reserve_frac),
            )
            .set(
                "time_model",
                Json::obj()
                    .set("alpha", self.time_model.alpha)
                    .set("beta", self.time_model.beta)
                    .set("c", self.time_model.c)
                    .set("gamma", self.time_model.gamma)
                    .set("delta", self.time_model.delta)
                    .set("lambda", self.time_model.lambda),
            )
            .set(
                "predictor",
                Json::obj()
                    .set("history_horizon", self.predictor.history_horizon)
                    .set("update_period", self.predictor.update_period)
                    .set("k_sigma", self.predictor.k_sigma),
            )
            .set("seed", self.seed)
    }

    pub fn from_json(j: &Json) -> Result<SystemConfig> {
        // Start from the paper preset so partial configs are valid.
        let mut c = SystemConfig::a100_llama8b();
        let f = |j: &Json, p: &str| j.at(p).and_then(Json::as_f64);
        let u = |j: &Json, p: &str| j.at(p).and_then(Json::as_usize);
        let b = |j: &Json, p: &str| j.at(p).and_then(Json::as_bool);

        if let Some(s) = j.at("model.name").and_then(Json::as_str) {
            c.model.name = s.to_string();
        }
        if let Some(v) = u(j, "model.n_layers") {
            c.model.n_layers = v;
        }
        if let Some(v) = u(j, "model.n_kv_heads") {
            c.model.n_kv_heads = v;
        }
        if let Some(v) = u(j, "model.head_dim") {
            c.model.head_dim = v;
        }
        if let Some(v) = u(j, "model.kv_dtype_bytes") {
            c.model.kv_dtype_bytes = v;
        }
        if let Some(v) = f(j, "slo.ttft") {
            c.slo.ttft = v;
        }
        if let Some(v) = f(j, "slo.tpot") {
            c.slo.tpot = v;
        }
        if let Some(s) = j.at("scheduler.kind").and_then(Json::as_str) {
            c.scheduler.kind = SchedulerKind::parse(s)?;
        }
        if let Some(v) = u(j, "scheduler.max_batch") {
            c.scheduler.max_batch = v;
        }
        if let Some(v) = u(j, "scheduler.max_batched_tokens") {
            c.scheduler.max_batched_tokens = v;
        }
        if let Some(v) = u(j, "scheduler.chunk") {
            c.scheduler.chunk = v;
        }
        if let Some(v) = u(j, "scheduler.mutation_budget") {
            c.scheduler.mutation_budget = v;
        }
        if let Some(v) = b(j, "scheduler.fast_forward") {
            c.scheduler.fast_forward = v;
        }
        if let Some(v) = u(j, "cache.block_size") {
            c.cache.block_size = v;
        }
        if let Some(v) = u(j, "cache.capacity_tokens") {
            c.cache.capacity_tokens = v;
        }
        if let Some(v) = b(j, "cache.task_aware") {
            c.cache.task_aware = v;
        }
        if let Some(v) = b(j, "cache.threshold") {
            c.cache.threshold = v;
        }
        if let Some(v) = f(j, "cache.reserve_frac") {
            c.cache.reserve_frac = v;
        }
        if let Some(v) = f(j, "time_model.alpha") {
            c.time_model.alpha = v;
        }
        if let Some(v) = f(j, "time_model.beta") {
            c.time_model.beta = v;
        }
        if let Some(v) = f(j, "time_model.c") {
            c.time_model.c = v;
        }
        if let Some(v) = f(j, "time_model.gamma") {
            c.time_model.gamma = v;
        }
        if let Some(v) = f(j, "time_model.delta") {
            c.time_model.delta = v;
        }
        if let Some(v) = f(j, "time_model.lambda") {
            c.time_model.lambda = v;
        }
        if let Some(v) = f(j, "predictor.history_horizon") {
            c.predictor.history_horizon = v;
        }
        if let Some(v) = f(j, "predictor.update_period") {
            c.predictor.update_period = v;
        }
        if let Some(v) = f(j, "predictor.k_sigma") {
            c.predictor.k_sigma = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            c.seed = v;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<SystemConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("config {path}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn validate(&self) -> Result<()> {
        if self.cache.block_size == 0 || self.cache.capacity_tokens < self.cache.block_size {
            return Err(anyhow!("cache capacity smaller than one block"));
        }
        if self.scheduler.max_batch == 0 || self.scheduler.max_batched_tokens == 0 {
            return Err(anyhow!("scheduler batch limits must be positive"));
        }
        if self.scheduler.chunk == 0 {
            return Err(anyhow!("chunk must be positive"));
        }
        if !(0.0..=1.0).contains(&self.time_model.lambda) {
            return Err(anyhow!("lambda must be in [0, 1]"));
        }
        if !(0.0..1.0).contains(&self.cache.reserve_frac) {
            return Err(anyhow!("reserve_frac must be in [0, 1)"));
        }
        if self.slo.ttft <= 0.0 || self.slo.tpot <= 0.0 {
            return Err(anyhow!("SLO bounds must be positive"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_valid() {
        SystemConfig::a100_llama8b().validate().unwrap();
        SystemConfig::cpu_echolm().validate().unwrap();
    }

    #[test]
    fn kv_bytes_per_token_llama8b() {
        let m = SystemConfig::a100_llama8b().model;
        // 2 * 32 layers * 8 heads * 128 dim * 2 bytes = 131072
        assert_eq!(m.kv_bytes_per_token(), 131_072);
    }

    #[test]
    fn json_roundtrip() {
        let c = SystemConfig::a100_llama8b();
        let j = c.to_json();
        let c2 = SystemConfig::from_json(&j).unwrap();
        assert_eq!(c2.scheduler.kind, c.scheduler.kind);
        assert_eq!(c2.cache.capacity_tokens, c.cache.capacity_tokens);
        assert_eq!(c2.time_model.beta, c.time_model.beta);
        assert_eq!(c2.seed, c.seed);
    }

    #[test]
    fn partial_json_overlays_preset() {
        let j = Json::parse(r#"{"scheduler": {"kind": "bs"}, "seed": 7}"#).unwrap();
        let c = SystemConfig::from_json(&j).unwrap();
        assert_eq!(c.scheduler.kind, SchedulerKind::Bs);
        assert_eq!(c.seed, 7);
        assert_eq!(c.cache.block_size, 16); // preserved from preset
    }

    #[test]
    fn scheduler_kind_parse_and_components() {
        assert_eq!(SchedulerKind::parse("echo").unwrap(), SchedulerKind::Echo);
        assert_eq!(SchedulerKind::parse("BS+E").unwrap(), SchedulerKind::BsE);
        assert!(SchedulerKind::parse("nope").is_err());
        assert!(!SchedulerKind::Bs.uses_estimator());
        assert!(SchedulerKind::BsE.uses_estimator());
        assert!(!SchedulerKind::BsE.uses_kv_aware_selection());
        assert!(SchedulerKind::BsES.uses_kv_aware_selection());
        assert!(!SchedulerKind::BsES.uses_task_aware_cache());
        assert!(SchedulerKind::Echo.uses_task_aware_cache());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = SystemConfig::a100_llama8b();
        c.time_model.lambda = 1.5;
        assert!(c.validate().is_err());
        let mut c = SystemConfig::a100_llama8b();
        c.cache.capacity_tokens = 4;
        assert!(c.validate().is_err());
    }
}

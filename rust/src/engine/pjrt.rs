//! Real-execution backend: EchoLM steps through the PJRT CPU client.
//!
//! Proves the three layers compose: the same scheduler/KV-manager decisions
//! that drive the simulation drive actual XLA executions here, and tokens
//! come from the model's greedy head, not a sampler stub.
//!
//! Slot mapping: the device KV slab has `max_batch` fixed slots; a request
//! gets a slot at first execution and keeps it until completion or
//! preemption. The slab is dense (no physical paging), so prefix-cache
//! fast-forward is disabled on this path (`cfg.scheduler` should keep
//! chunked prefill on; logical block accounting still runs above) — see
//! DESIGN.md "Hardware adaptation".

use anyhow::{anyhow, bail, Result};

use super::ExecutionBackend;
use crate::core::{RequestId, RequestStore, Token};
use crate::utils::hash::FxHashMap;
use crate::runtime::ModelRuntime;
use crate::scheduler::{Plan, WorkKind};

pub struct PjrtBackend {
    pub rt: ModelRuntime,
    slots: FxHashMap<RequestId, usize>,
    free_slots: Vec<usize>,
}

impl PjrtBackend {
    pub fn new(rt: ModelRuntime) -> Self {
        let b = rt.manifest.max_batch;
        PjrtBackend {
            rt,
            slots: FxHashMap::default(),
            free_slots: (0..b).rev().collect(),
        }
    }

    pub fn max_batch(&self) -> usize {
        self.rt.manifest.max_batch
    }

    fn slot_for(&mut self, req: RequestId) -> Result<usize> {
        if let Some(&s) = self.slots.get(&req) {
            return Ok(s);
        }
        let s = self
            .free_slots
            .pop()
            .ok_or_else(|| anyhow!("no free device slots (batch > max_batch?)"))?;
        self.slots.insert(req, s);
        Ok(s)
    }

    /// The token at sequence position `pos` of a request (prompt, then
    /// generated continuation).
    fn token_at(store: &RequestStore, req: RequestId, pos: usize) -> Result<Token> {
        let r = store.get(req);
        let prompt = r
            .prompt
            .tokens
            .as_ref()
            .ok_or_else(|| anyhow!("PJRT backend needs real token prompts"))?;
        if pos < prompt.len() {
            Ok(prompt[pos])
        } else {
            r.out_tokens
                .get(pos - prompt.len())
                .copied()
                .ok_or_else(|| anyhow!("position {pos} beyond generated tokens"))
        }
    }
}

impl ExecutionBackend for PjrtBackend {
    fn execute(
        &mut self,
        plan: &Plan,
        store: &RequestStore,
        result_tokens: &mut Vec<Option<Token>>,
    ) -> Result<f64> {
        let b = self.rt.manifest.max_batch;
        if plan.items.len() > b {
            bail!("plan has {} items but device has {b} slots", plan.items.len());
        }
        // Bucket = smallest chunk width covering every item.
        let widest = plan
            .items
            .iter()
            .map(|i| match i.kind {
                WorkKind::Prefill { chunk } => chunk,
                WorkKind::Decode => 1,
            })
            .max()
            .unwrap_or(1);
        let bucket = self.rt.bucket_for(widest)?;

        let mut tokens = vec![0i32; b * bucket];
        let mut cache_lens = vec![0i32; b];
        let mut q_lens = vec![0i32; b];
        let mut slot_of_item = Vec::with_capacity(plan.items.len());
        for item in &plan.items {
            let slot = self.slot_for(item.req)?;
            slot_of_item.push(slot);
            let r = store.get(item.req);
            let (start, width) = match item.kind {
                WorkKind::Prefill { chunk } => (r.computed, chunk),
                WorkKind::Decode => (r.computed, 1),
            };
            debug_assert!(
                start + width <= r.seq_len(),
                "work window {}..{} beyond seq {}",
                start,
                start + width,
                r.seq_len()
            );
            for i in 0..width {
                tokens[slot * bucket + i] = Self::token_at(store, item.req, start + i)? as i32;
            }
            cache_lens[slot] = start as i32;
            q_lens[slot] = width as i32;
        }

        let t0 = std::time::Instant::now();
        let out = self.rt.step(bucket, &tokens, &cache_lens, &q_lens)?;
        let elapsed = t0.elapsed().as_secs_f64();

        result_tokens.extend(plan.items.iter().zip(&slot_of_item).map(|(item, &slot)| {
            let emitting = match item.kind {
                WorkKind::Decode => true,
                WorkKind::Prefill { chunk } => store.get(item.req).remaining_prefill() <= chunk,
            };
            emitting.then(|| out.next_tokens[slot] as Token)
        }));
        Ok(elapsed)
    }

    fn on_release(&mut self, req: RequestId) {
        if let Some(slot) = self.slots.remove(&req) {
            self.free_slots.push(slot);
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

//! Iteration engine: the vLLM-like serving loop Echo's components plug
//! into. Each step = admit arrivals → schedule (plan) → execute on a
//! backend → account tokens/completions/metrics.
//!
//! Backends:
//!   * [`sim::SimBackend`]   — discrete-event cost-model execution (the
//!     paper's evaluation scale: A100 + LLaMA-8B coefficients);
//!   * `PjrtBackend` (runtime feature) — real EchoLM steps through the
//!     PJRT CPU client, proving L1-L3 compose.

#[cfg(feature = "runtime")]
pub mod pjrt;
pub mod sim;

use std::collections::{BTreeSet, VecDeque};

use crate::config::{SystemConfig, SchedulerKind};
use crate::core::{ReqState, Request, RequestId, RequestStore, TaskClass, Token};
use crate::estimator::{MemoryPredictor, TimeModel};
use crate::faults::{backoff_delay, ReplicaFaults, ServeError, MAX_EXEC_ATTEMPTS};
use crate::kvcache::{EvictionPolicy, KvManager};
use crate::metrics::{Metrics, SampleCtl};
use crate::obs::{TraceEvent, TraceRing};
use crate::scheduler::{OfflinePool, Outcome, Plan, Scheduler, WorkKind};
use crate::utils::hash::FxHashSet;

pub trait ExecutionBackend {
    /// Execute `plan`, appending exactly one entry per plan item to
    /// `tokens` (passed cleared; the caller recycles the buffer so the
    /// step loop stays allocation-free) and returning the execution time
    /// in seconds (virtual for sim, wall for PJRT). Decodes always emit a
    /// token; prefill chunks emit iff they complete the request's prefill
    /// this iteration.
    fn execute(
        &mut self,
        plan: &Plan,
        store: &RequestStore,
        tokens: &mut Vec<Option<Token>>,
    ) -> anyhow::Result<f64>;
    /// A request left the running set (finished or preempted) — free any
    /// backend slot state.
    fn on_release(&mut self, _req: RequestId) {}
    fn name(&self) -> &'static str;
}

/// Reusable per-iteration buffers owned by the engine. Every vector is
/// cleared and refilled in place each step, so a steady-state iteration
/// (carried-over batch, no admissions or completions) performs no heap
/// allocation — the hot loop touches only recycled capacity.
#[derive(Default)]
struct StepScratch {
    /// Scheduler outcome (plan items + batch shape + admission lists),
    /// recycled through `Scheduler::schedule_into`.
    outcome: Outcome,
    /// Backend token output, one slot per plan item.
    tokens: Vec<Option<Token>>,
    /// Requests completed this iteration.
    finished: Vec<RequestId>,
    /// Capacity-growth events on the engine-side scratch buffers
    /// (regression hook; see [`Engine::step_alloc_growth`]).
    grows: u64,
}

/// Capacity snapshot of the recycled outcome's vectors — the single
/// source of truth for the growth regression hook (a buffer missing here
/// would silently escape [`Engine::step_alloc_growth`]).
fn outcome_caps(out: &Outcome) -> [usize; 6] {
    [
        out.plan.items.capacity(),
        out.plan.shape.prefills.capacity(),
        out.plan.shape.decode_lens.capacity(),
        out.admitted_online.capacity(),
        out.admitted_offline.capacity(),
        out.preempted.capacity(),
    ]
}

pub struct Engine<B: ExecutionBackend> {
    pub cfg: SystemConfig,
    pub store: RequestStore,
    pub online_queue: VecDeque<RequestId>,
    pub pool: OfflinePool,
    pub kv: KvManager,
    pub sched: Scheduler,
    pub predictor: MemoryPredictor,
    pub metrics: Metrics,
    pub backend: B,
    pub clock: f64,
    /// Future online arrivals (sorted ascending; replayed into the queue).
    arrivals: VecDeque<(f64, RequestId)>,
    /// Ids currently sitting in `online_queue` (admission pending). The
    /// id-indexed membership check lets `cancel` decide in O(1) whether a
    /// queued online request is in the admission queue or still a future
    /// arrival, instead of scanning both structures. Deterministic fast
    /// hashing (ids are system-generated, never attacker-chosen).
    in_queue: FxHashSet<RequestId>,
    /// Reusable step-loop buffers (see [`StepScratch`]).
    scratch: StepScratch,
    /// Unfinished requests this engine owns (submitted, neither finished
    /// nor withdrawn). The store keeps every request ever for metrics, so
    /// load/digest scans iterate this set instead of the full history.
    live: BTreeSet<RequestId>,
    sample: SampleCtl,
    /// Iteration-level trace collector (PR 6 observability). `None` =
    /// tracing disabled: every hook below is a single `is_some` branch and
    /// the steady step loop stays allocation-free. Enabled, the ring is
    /// pre-allocated and `push` never allocates either.
    trace: Option<TraceRing>,
    /// Fault-injection schedule (PR 7). `None` = injection disabled: the
    /// execute path pays a single `is_some` branch, exactly like the trace
    /// hook, and the steady step loop stays allocation-free. Installed, the
    /// schedule is consulted around `ExecutionBackend::execute` — slowdown
    /// windows stretch the reported elapsed time, transient faults fail
    /// attempts that the retry loop below absorbs with capped exponential
    /// backoff on the virtual clock.
    faults: Option<ReplicaFaults>,
    /// Hard stop against pathological loops; generous (24 h at 10 ms/iter).
    pub max_iterations: usize,
    /// Ceiling for idle-time jumps: when the engine is idle it fast-forwards
    /// to the next arrival, but never past this cap. `run_until` pins it to
    /// the deadline so co-simulated engines (cluster replicas stepped in
    /// sync quanta) stay time-aligned instead of overshooting the quantum.
    clock_cap: f64,
}

impl<B: ExecutionBackend> Engine<B> {
    pub fn new(cfg: SystemConfig, backend: B) -> Self {
        let policy = if cfg.scheduler.kind.uses_task_aware_cache() && cfg.cache.task_aware {
            EvictionPolicy::TaskAware
        } else {
            EvictionPolicy::Lru
        };
        let kv = KvManager::new(cfg.capacity_blocks(), cfg.cache.block_size, policy);
        let sched = Scheduler::new(
            cfg.scheduler.clone(),
            cfg.slo,
            TimeModel::new(cfg.time_model),
            cfg.cache.block_size,
        );
        let predictor = MemoryPredictor::new(cfg.predictor);
        Engine {
            store: RequestStore::new(),
            online_queue: VecDeque::new(),
            pool: OfflinePool::default_buckets(),
            kv,
            sched,
            predictor,
            metrics: Metrics::default(),
            backend,
            clock: 0.0,
            arrivals: VecDeque::new(),
            in_queue: FxHashSet::default(),
            scratch: StepScratch::default(),
            live: BTreeSet::new(),
            sample: SampleCtl::new(0.0),
            trace: None,
            faults: None,
            max_iterations: 10_000_000,
            clock_cap: f64::INFINITY,
            cfg,
        }
    }

    /// Configure series sampling cadence (seconds of sim time per point).
    /// The previous sample anchor is preserved, so mid-run reconfiguration
    /// (or a cluster re-applying the interval at quantum boundaries) does
    /// not make sampling drift or double-sample.
    pub fn set_sample_interval(&mut self, dt: f64) {
        let last = self.sample.last_sample();
        self.sample = SampleCtl::new(dt);
        self.sample.reset(last);
    }

    /// Enable iteration-level tracing with a ring of `events` capacity
    /// (see [`crate::obs`]). Allocates the ring once, here; the step loop
    /// itself never allocates for tracing.
    pub fn enable_trace(&mut self, events: usize) {
        self.trace = Some(TraceRing::with_capacity(events));
    }

    /// The trace collector, if tracing is enabled.
    pub fn trace(&self) -> Option<&TraceRing> {
        self.trace.as_ref()
    }

    /// Detach the trace collector, disabling tracing from here on.
    pub fn take_trace(&mut self) -> Option<TraceRing> {
        self.trace.take()
    }

    /// Install a per-replica fault schedule (see [`crate::faults`]). An
    /// empty schedule is not installed at all, keeping the disabled path
    /// identical to a fault-free engine.
    pub fn install_faults(&mut self, f: ReplicaFaults) {
        self.faults = if f.is_empty() { None } else { Some(f) };
    }

    /// Whether a fault schedule is installed.
    pub fn faults_installed(&self) -> bool {
        self.faults.is_some()
    }

    /// SLO-guard actuator (PR 9): offline tokens-per-batch cap for this
    /// replica's scheduler. `usize::MAX` disarms it — the guard-off path
    /// stays a single never-taken comparison inside `schedule_into`.
    pub fn set_offline_cap(&mut self, cap: usize) {
        self.sched.set_offline_cap(cap);
    }

    /// SLO-guard actuator (PR 9): pause/resume new offline admissions
    /// (resident offline work keeps draining under the cap).
    pub fn set_offline_admit_paused(&mut self, paused: bool) {
        self.sched.set_offline_admit_paused(paused);
    }

    /// SLO-guard Emergency actuator (PR 9): preempt every running offline
    /// request on this replica (recompute mode — victims return to the
    /// pool). Coordinator-phase only; returns the number preempted.
    pub fn preempt_all_offline(&mut self) -> usize {
        let victims = self
            .sched
            .preempt_all_offline(&mut self.store, &mut self.pool, &mut self.kv);
        self.metrics.preemptions += victims.len();
        for &victim in &victims {
            self.backend.on_release(victim);
            if self.trace.is_some() {
                let cost = self.store.get(victim).seq_len() as u32;
                self.trace_push(TraceEvent::Preempt {
                    t: self.clock,
                    req: victim,
                    cost_tokens: cost,
                });
            }
        }
        victims.len()
    }

    #[inline]
    pub(crate) fn trace_push(&mut self, ev: TraceEvent) {
        if let Some(tr) = self.trace.as_mut() {
            tr.push(ev);
        }
    }

    /// Queue an online request for arrival at `req.arrival` (>= clock).
    pub fn submit_online(&mut self, req: Request) {
        debug_assert_eq!(req.class, TaskClass::Online);
        let t = req.arrival;
        let id = req.id;
        self.live.insert(id);
        self.store.insert(req);
        // Insert keeping `arrivals` sorted (submissions are usually already
        // in order; fall back to a scan when not).
        match self.arrivals.back() {
            Some(&(last, _)) if last <= t => self.arrivals.push_back((t, id)),
            _ => {
                let pos = self.arrivals.partition_point(|&(a, _)| a <= t);
                self.arrivals.insert(pos, (t, id));
            }
        }
        self.metrics.online_arrivals.push(t, 1.0);
        self.trace_push(TraceEvent::Submit {
            t,
            req: id,
            online: true,
        });
    }

    /// Register an offline request in the pool (available immediately).
    pub fn submit_offline(&mut self, req: Request) {
        debug_assert_eq!(req.class, TaskClass::Offline);
        let id = req.id;
        self.store.insert(req);
        self.register_offline(id);
    }

    /// Register an offline request already sitting in the store (workload
    /// generators insert directly): intern its key path, register future
    /// interest with the KV manager, and pool it. The single entry point
    /// for the previously copy-pasted register-future-then-pool sequence.
    pub fn register_offline(&mut self, id: RequestId) {
        let block_size = self.cfg.cache.block_size;
        let prompt_len = self.store.get(id).prompt.total_len;
        let keys = self.store.get(id).content_key_path(block_size).to_vec();
        self.kv.register_future(&keys);
        self.pool.add(id, prompt_len, keys);
        self.live.insert(id);
        self.trace_push(TraceEvent::Submit {
            t: self.clock,
            req: id,
            online: false,
        });
    }

    /// Withdraw a pooled offline request from this engine (cluster
    /// work-stealing / drain): drop pool + future-interest registration and
    /// demote the store entry to an inert `Queued` record. The job itself
    /// moves elsewhere as a spec.
    pub fn withdraw_offline(&mut self, id: RequestId) {
        let block_size = self.cfg.cache.block_size;
        let prompt_len = self.store.get(id).prompt.total_len;
        self.pool.remove(id, prompt_len);
        self.kv
            .unregister_future(self.store.get(id).content_key_path(block_size));
        let r = self.store.get_mut(id);
        r.state = ReqState::Queued;
        r.release_interned_keys();
        self.live.remove(&id);
    }

    /// Cancel a live request (client withdrawal through the serving API).
    /// Terminal like completion, but nothing is delivered: the request's
    /// KV blocks and future-key interest, pool/queue entries, scheduler
    /// tracking, and interned content keys are all released, and the store
    /// keeps an inert `Cancelled` record for metrics. Returns false when
    /// the id is unknown or already terminal (finished, withdrawn).
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if self.store.try_get(id).is_none() || !self.live.contains(&id) {
            return false;
        }
        let block_size = self.cfg.cache.block_size;
        let (class, state, prompt_len, arrival) = {
            let r = self.store.get(id);
            (r.class, r.state, r.prompt.total_len, r.arrival)
        };
        match state {
            ReqState::Finished | ReqState::Cancelled => return false,
            ReqState::Queued => match class {
                TaskClass::Online => {
                    // Sitting in the admission queue (id-indexed membership
                    // check), or not yet arrived (binary search on the
                    // time-sorted arrivals vec) — never a full scan of both.
                    if self.in_queue.remove(&id) {
                        if let Some(pos) = self.online_queue.iter().position(|&rid| rid == id) {
                            let _ = self.online_queue.remove(pos);
                        }
                    } else {
                        let start = self.arrivals.partition_point(|&(t, _)| t < arrival);
                        for i in start..self.arrivals.len() {
                            let (t, rid) = self.arrivals[i];
                            if t > arrival {
                                break;
                            }
                            if rid == id {
                                let _ = self.arrivals.remove(i);
                                break;
                            }
                        }
                    }
                }
                TaskClass::Offline => {
                    let keys = self.store.get(id).content_key_path(block_size).to_vec();
                    self.pool.remove(id, prompt_len);
                    self.kv.unregister_future(&keys);
                }
            },
            // Preempted requests live in the offline pool (recompute mode).
            ReqState::Preempted => {
                let keys = self.store.get(id).content_key_path(block_size).to_vec();
                self.pool.remove(id, prompt_len);
                if class == TaskClass::Offline {
                    self.kv.unregister_future(&keys);
                }
            }
            ReqState::Running => {
                self.kv.release(id, false);
                if class == TaskClass::Offline {
                    let keys = self.store.get(id).content_key_path(block_size).to_vec();
                    self.kv.unregister_future(&keys);
                }
                self.sched.on_finished(id);
                self.backend.on_release(id);
            }
        }
        let r = self.store.get_mut(id);
        r.state = ReqState::Cancelled;
        r.release_interned_keys();
        self.live.remove(&id);
        self.metrics.record_cancellation(class);
        self.trace_push(TraceEvent::Cancel {
            t: self.clock,
            req: id,
        });
        true
    }

    /// Unfinished requests owned by this engine (deterministic id order).
    pub fn live_requests(&self) -> impl Iterator<Item = &Request> {
        self.live.iter().map(|&id| self.store.get(id))
    }

    fn online_kv_tokens(&self) -> usize {
        self.live_requests()
            .filter(|r| r.class == TaskClass::Online && r.state == ReqState::Running)
            .map(|r| self.kv.held_blocks(r.id) * self.cfg.cache.block_size)
            .sum()
    }

    fn active_counts(&self) -> (usize, usize) {
        let mut online = 0;
        let mut offline = 0;
        for r in self.live_requests() {
            if r.state == ReqState::Running {
                match r.class {
                    TaskClass::Online => online += 1,
                    TaskClass::Offline => offline += 1,
                }
            }
        }
        (online, offline)
    }

    fn finish_request(&mut self, id: RequestId) {
        let (class, tokens_out, ttft, tpot, prompt_len) = {
            let r = self.store.get(id);
            (
                r.class,
                r.generated,
                r.ttft(),
                r.mean_tpot(),
                r.prompt.total_len,
            )
        };
        self.kv.release(id, true);
        if class == TaskClass::Offline {
            let block_size = self.cfg.cache.block_size;
            self.kv
                .unregister_future(self.store.get(id).content_key_path(block_size));
        }
        self.sched.on_finished(id);
        self.backend.on_release(id);
        self.live.remove(&id);
        // The store retains the finished request for metrics; its interned
        // key vectors are dead weight from here on.
        self.store.get_mut(id).release_interned_keys();
        self.metrics
            .record_completion(class, tokens_out, prompt_len, ttft, tpot);
    }

    /// One engine iteration. Returns false when no work remains (or the
    /// remaining work can never be scheduled). In steady state (carried
    /// batch, no admissions/completions) the loop allocates nothing: plan,
    /// token, and finished buffers are recycled through [`StepScratch`].
    // lint: hot-path
    pub fn step(&mut self) -> anyhow::Result<bool> {
        // 1. replay due arrivals
        while matches!(self.arrivals.front(), Some(&(t, _)) if t <= self.clock) {
            // lint: allow-unwrap(the matches! loop condition saw Some(front))
            let (_, id) = self.arrivals.pop_front().unwrap();
            self.online_queue.push_back(id);
            self.in_queue.insert(id);
        }

        // 2. schedule (into the recycled outcome)
        // KV stats snapshot for the per-iteration delta event (trace only;
        // `CacheStats` is a handful of counters, the clone is heap-free).
        let kv_before = if self.trace.is_some() {
            // lint: allow-alloc(CacheStats is a few counters; the clone is heap-free)
            Some(self.kv.stats.clone())
        } else {
            None
        };
        let mut outcome = std::mem::take(&mut self.scratch.outcome);
        let out_caps = outcome_caps(&outcome);
        self.sched.schedule_into(
            self.clock,
            &mut self.store,
            &mut self.online_queue,
            &mut self.pool,
            &mut self.kv,
            &mut outcome,
        );
        // Capacities never shrink, so any change means a buffer grew.
        if outcome_caps(&outcome) != out_caps {
            self.scratch.grows += 1;
        }
        for &id in &outcome.admitted_online {
            self.in_queue.remove(&id);
            let wait = (self.clock - self.store.get(id).arrival).max(0.0);
            self.metrics.queue_wait_hist.record(wait);
            self.trace_push(TraceEvent::Admit {
                t: self.clock,
                req: id,
                online: true,
                wait,
            });
        }
        if self.trace.is_some() {
            for &id in &outcome.admitted_offline {
                let wait = (self.clock - self.store.get(id).arrival).max(0.0);
                self.trace_push(TraceEvent::Admit {
                    t: self.clock,
                    req: id,
                    online: false,
                    wait,
                });
            }
        }
        self.metrics.preemptions += outcome.preempted.len();
        self.metrics.skipped_offline += outcome.skipped_offline;
        for &victim in &outcome.preempted {
            self.backend.on_release(victim);
            if self.trace.is_some() {
                // `seq_len` tokens must be re-prefilled on re-admission
                // (modulo prefix-cache hits) — the recompute cost Eq. 2
                // punishes.
                let cost = self.store.get(victim).seq_len() as u32;
                self.trace_push(TraceEvent::Preempt {
                    t: self.clock,
                    req: victim,
                    cost_tokens: cost,
                });
            }
        }

        if outcome.plan.is_empty() {
            self.scratch.outcome = outcome;
            // Idle: jump to the next arrival if any (never past the cap).
            if let Some(&(t, _)) = self.arrivals.front() {
                self.clock = self.clock.max(t.min(self.clock_cap));
                return Ok(true);
            }
            // No arrivals and nothing runnable. Any requests stuck in the
            // queue/pool can never be scheduled (e.g. larger than memory).
            if !self.online_queue.is_empty() || !self.pool.is_empty() {
                log::warn!(
                    "engine idle with {} queued / {} pooled unschedulable requests",
                    self.online_queue.len(),
                    self.pool.len()
                );
            }
            return Ok(false);
        }

        // 3. execute (into the recycled token buffer), absorbing transient
        // faults. Injected faults (the schedule in `self.faults`) and real
        // backend errors share one policy: capped exponential backoff on
        // the virtual clock, escalating to a typed replica-fatal
        // `ServeError::ExecFailed` once MAX_EXEC_ATTEMPTS have all failed.
        // The vendored anyhow has no downcast, so classification happens
        // here, before the error crosses the anyhow boundary: anything
        // that escapes `step` is final, never retriable.
        let mut tokens = std::mem::take(&mut self.scratch.tokens);
        tokens.clear();
        let tok_cap = tokens.capacity();
        let injected = match self.faults.as_mut() {
            Some(f) => f.take_exec_failures(self.clock).unwrap_or(0),
            None => 0,
        };
        let mut failed_attempts = 0u32;
        let exec: Result<f64, ServeError> = loop {
            if failed_attempts < injected {
                // Scheduled transient fault: this attempt fails by plan.
                failed_attempts += 1;
            } else {
                tokens.clear();
                match self.backend.execute(&outcome.plan, &self.store, &mut tokens) {
                    Ok(elapsed) => break Ok(elapsed),
                    Err(e) => {
                        failed_attempts += 1;
                        if failed_attempts >= MAX_EXEC_ATTEMPTS {
                            break Err(ServeError::ExecFailed {
                                attempts: failed_attempts,
                                last: e.to_string(),
                            });
                        }
                    }
                }
                continue;
            }
            if failed_attempts >= MAX_EXEC_ATTEMPTS {
                break Err(ServeError::ExecFailed {
                    attempts: failed_attempts,
                    last: "injected transient fault".into(),
                });
            }
        };
        if failed_attempts > 0 {
            self.metrics.exec_faults += failed_attempts as u64;
            // Waiting out the backoff is idle time, not busy time.
            self.clock += backoff_delay(failed_attempts);
        }
        let elapsed = match exec {
            Ok(elapsed) => {
                if failed_attempts > 0 {
                    self.metrics.exec_retries += 1;
                }
                match self.faults.as_ref() {
                    Some(f) => elapsed * f.slow_factor(self.clock),
                    None => elapsed,
                }
            }
            Err(e) => {
                self.scratch.outcome = outcome;
                self.scratch.tokens = tokens;
                return Err(e.into());
            }
        };
        let iter_start = self.clock;
        self.clock += elapsed;
        self.metrics.busy_time += elapsed;
        self.metrics.iterations += 1;
        // Estimator audit: predicted batch time (Eq. 8) vs what the
        // backend reported (no-op when the estimator made no prediction).
        self.metrics.record_estimate(outcome.plan.est_time, elapsed);
        if self.trace.is_some() {
            let mut prefills = 0u32;
            let mut decodes = 0u32;
            let mut batch_tokens = 0u32;
            for item in &outcome.plan.items {
                match item.kind {
                    WorkKind::Prefill { chunk } => {
                        prefills += 1;
                        batch_tokens += chunk as u32;
                    }
                    WorkKind::Decode => {
                        decodes += 1;
                        batch_tokens += 1;
                    }
                }
            }
            self.trace_push(TraceEvent::Iteration {
                start: iter_start,
                dur: elapsed,
                prefills,
                decodes,
                tokens: batch_tokens,
                trials: outcome.trials as u32,
                est: outcome.plan.est_time,
            });
        }

        // 4. token/completion accounting
        debug_assert_eq!(tokens.len(), outcome.plan.items.len());
        let mut finished = std::mem::take(&mut self.scratch.finished);
        finished.clear();
        let fin_cap = finished.capacity();
        let slo = self.cfg.slo;
        for (item, token) in outcome.plan.items.iter().zip(&tokens) {
            let r = self.store.get_mut(item.req);
            let deadline = r.next_token_deadline(&slo);
            let mut emitted = false;
            match item.kind {
                WorkKind::Prefill { chunk } => {
                    r.computed += chunk;
                    self.metrics.prefill_tokens_computed += chunk as u64;
                    debug_assert!(r.computed <= r.seq_len());
                    if r.computed >= r.seq_len() {
                        // Prefill completed: the first (or next, after a
                        // preemption re-prefill) token lands now. The
                        // emitted token's own KV is not resident yet, so
                        // computed stays at the old seq_len = new seq_len-1.
                        emitted = true;
                        let first = r.first_token_at.is_none();
                        if r.record_token(self.clock, *token) {
                            finished.push(item.req);
                        }
                        if first {
                            self.trace_push(TraceEvent::FirstToken {
                                t: self.clock,
                                req: item.req,
                            });
                        }
                    }
                }
                WorkKind::Decode => {
                    // The decode step wrote the consumed token's KV.
                    r.computed += 1;
                    debug_assert_eq!(r.computed, r.seq_len());
                    emitted = true;
                    if r.record_token(self.clock, *token) {
                        finished.push(item.req);
                    }
                }
            }
            if emitted && self.store.get(item.req).class == TaskClass::Online {
                self.metrics.online_tokens_checked += 1;
                if self.clock <= deadline {
                    self.metrics.online_token_deadlines_met += 1;
                }
            }
        }
        for &id in &finished {
            if self.trace.is_some() {
                let (online, tokens_out) = {
                    let r = self.store.get(id);
                    (r.class == TaskClass::Online, r.generated as u32)
                };
                self.trace_push(TraceEvent::Finish {
                    t: self.clock,
                    req: id,
                    online,
                    tokens: tokens_out,
                });
            }
            self.finish_request(id);
        }
        // KV activity delta over this iteration (schedule + execute +
        // completions), emitted only when some counter moved.
        if let Some(before) = kv_before {
            let s = &self.kv.stats;
            let lookups = (s.lookup_blocks - before.lookup_blocks) as u32;
            let hits = (s.hit_blocks - before.hit_blocks) as u32;
            let evictions = (s.evictions - before.evictions) as u32;
            let superseded = (s.superseded - before.superseded) as u32;
            if lookups + hits + evictions + superseded > 0 {
                self.trace_push(TraceEvent::Kv {
                    t: self.clock,
                    lookups,
                    hits,
                    evictions,
                    superseded,
                });
            }
        }
        if tokens.capacity() > tok_cap || finished.capacity() > fin_cap {
            self.scratch.grows += 1;
        }
        finished.clear();
        self.scratch.outcome = outcome;
        self.scratch.tokens = tokens;
        self.scratch.finished = finished;

        // 5. predictor + threshold (Echo's cache manager input)
        self.predictor.observe(self.clock, self.online_kv_tokens() as f64);
        if self.cfg.cache.threshold && self.cfg.scheduler.kind == SchedulerKind::Echo {
            let floor = self.cfg.cache.reserve_frac * self.cfg.cache.capacity_tokens as f64;
            let cap = 0.5 * self.cfg.cache.capacity_tokens as f64;
            let predicted = self.predictor.reserve_tokens(self.clock);
            self.kv
                .set_reserve_tokens(predicted.clamp(floor, cap) as usize);
        }

        // 6. series sampling
        if self.sample.due(self.clock) {
            let (on, off) = self.active_counts();
            self.metrics.active_online.push(self.clock, on as f64);
            self.metrics.active_offline.push(self.clock, off as f64);
            let (running, c_on, c_off, free) = self.kv.occupancy_breakdown();
            let bs = self.cfg.cache.block_size as f64;
            self.metrics.mem_running.push(self.clock, running as f64 * bs);
            self.metrics.mem_cached_online.push(self.clock, c_on as f64 * bs);
            self.metrics
                .mem_cached_offline
                .push(self.clock, c_off as f64 * bs);
            self.metrics.mem_free.push(self.clock, free as f64 * bs);
            self.metrics
                .hit_ratio
                .push(self.clock, self.kv.stats.hit_ratio());
            self.metrics
                .cache_lookups_cum
                .push(self.clock, self.kv.stats.lookup_blocks as f64);
            self.metrics
                .cache_hits_cum
                .push(self.clock, self.kv.stats.hit_blocks as f64);
        }
        self.metrics.prefill_tokens_saved = self.kv.stats.saved_tokens;

        Ok(true)
    }

    /// Online requests accepted but not yet running (future arrivals plus
    /// the admission queue) — part of the cluster load digest.
    pub fn backlog_online(&self) -> usize {
        self.arrivals.len() + self.online_queue.len()
    }

    /// Capacity-growth events on the step loop's recycled buffers since
    /// construction (engine scratch + the scheduler's partition scratch) —
    /// the allocation regression hook alongside
    /// `Request::key_compute_count`: steady-state iterations must leave it
    /// flat (the bench additionally pins allocator-level zero via a
    /// counting global allocator).
    pub fn step_alloc_growth(&self) -> u64 {
        self.scratch.grows + self.sched.scratch_grows()
    }

    /// `KvManager::availability` invocations since construction — the
    /// companion regression hook: availability is O(1) now, but the
    /// scheduler must still take **one snapshot per admission round** (not
    /// one per candidate trial), so this counter stays flat in candidate
    /// count. Steady-state decode steps (no admissions, no block-boundary
    /// growth) make zero calls.
    pub fn kv_availability_calls(&self) -> u64 {
        self.kv.availability_calls()
    }

    /// Run until idle or `deadline` (sim clock), whichever first. Idle
    /// fast-forwards are capped at the deadline, so repeated `run_until`
    /// calls over consecutive quanta replay exactly like one long call.
    pub fn run_until(&mut self, deadline: f64) -> anyhow::Result<()> {
        let prev_cap = self.clock_cap;
        self.clock_cap = self.clock_cap.min(deadline);
        let mut iters = 0usize;
        let result = loop {
            if self.clock >= deadline {
                break Ok(());
            }
            match self.step() {
                Ok(true) => {}
                Ok(false) => break Ok(()),
                Err(e) => break Err(e),
            }
            iters += 1;
            if iters >= self.max_iterations {
                break Err(ServeError::IterationBackstop {
                    max_iterations: self.max_iterations,
                }
                .into());
            }
        };
        self.clock_cap = prev_cap;
        result
    }

    /// Run to completion of all submitted work.
    pub fn run(&mut self) -> anyhow::Result<()> {
        self.run_until(f64::INFINITY)
    }
}

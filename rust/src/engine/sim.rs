//! Discrete-event simulation backend: executes plans on a virtual clock
//! driven by the execution-time model (Eqs. 6-8) with small multiplicative
//! jitter. This is the substrate for the paper-scale evaluation (A100 +
//! LLaMA-8B coefficients) — the scheduler/KV-manager code above it is
//! exactly the code the real PJRT backend runs.

use super::ExecutionBackend;
use crate::core::{RequestStore, Token};
use crate::estimator::TimeModel;
use crate::scheduler::{Plan, WorkKind};
use crate::utils::rng::Rng;

pub struct SimBackend {
    pub time_model: TimeModel,
    rng: Rng,
    /// Multiplicative execution-time jitter sigma (0 = deterministic).
    pub jitter: f64,
    /// Floor on any executed iteration (framework overhead).
    pub floor: f64,
}

impl SimBackend {
    pub fn new(time_model: TimeModel, seed: u64, jitter: f64) -> Self {
        SimBackend {
            time_model,
            rng: Rng::new(seed),
            jitter,
            floor: 1e-4,
        }
    }
}

impl ExecutionBackend for SimBackend {
    fn execute(
        &mut self,
        plan: &Plan,
        store: &RequestStore,
        tokens: &mut Vec<Option<Token>>,
    ) -> anyhow::Result<f64> {
        let base = self.time_model.batch_time(&plan.shape);
        let noise = if self.jitter > 0.0 {
            (1.0 + self.jitter * self.rng.normal()).max(0.5)
        } else {
            1.0
        };
        let elapsed = (base * noise).max(self.floor);
        tokens.extend(plan.items.iter().map(|item| match item.kind {
            WorkKind::Decode => Some(0),
            WorkKind::Prefill { chunk } => {
                // Completing chunk emits the first token.
                if store.get(item.req).remaining_prefill() <= chunk {
                    Some(0)
                } else {
                    None
                }
            }
        }));
        Ok(elapsed)
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SchedulerKind, SystemConfig};
    use crate::core::{PromptSpec, Request, TaskClass};
    use crate::engine::Engine;
    use crate::workload::{synthesize, DatasetSpec};
    use crate::utils::rng::Rng;

    fn engine(kind: SchedulerKind) -> Engine<SimBackend> {
        let mut cfg = SystemConfig::a100_llama8b();
        cfg.scheduler.kind = kind;
        cfg.cache.capacity_tokens = 50_000;
        let backend = SimBackend::new(
            crate::estimator::TimeModel::new(cfg.time_model),
            1,
            0.0,
        );
        Engine::new(cfg, backend)
    }

    #[test]
    fn single_online_request_completes_within_slo() {
        let mut e = engine(SchedulerKind::Echo);
        let id = e.store.fresh_id();
        e.submit_online(Request::new(
            id,
            TaskClass::Online,
            0.0,
            PromptSpec::sim(500, None),
            20,
        ));
        e.run().unwrap();
        let r = e.store.get(id);
        assert!(r.is_finished());
        assert_eq!(r.generated, 20);
        let ttft = r.ttft().unwrap();
        assert!(ttft < 1.0, "ttft {ttft}");
        assert!(e.metrics.online_completed == 1);
        e.kv.check_invariants().unwrap();
    }

    #[test]
    fn offline_batch_completes_and_counts_tokens() {
        let mut e = engine(SchedulerKind::Echo);
        let mut rng = Rng::new(3);
        let spec = DatasetSpec::loogle_qa_short().scaled(0.05); // ~400-token prompts
        let batch = synthesize(&spec, 10, TaskClass::Offline, 0.0, &mut e.store, &mut rng);
        let expected: u64 = batch
            .ids
            .iter()
            .map(|&id| e.store.get(id).max_new_tokens as u64)
            .sum();
        // Requests already inserted in the store by synthesize; register them.
        for &id in &batch.ids {
            e.register_offline(id);
        }
        e.run().unwrap();
        assert_eq!(e.metrics.offline_completed, 10);
        assert_eq!(e.metrics.offline_tokens_out, expected);
        assert!(e.metrics.offline_throughput() > 0.0);
        e.kv.check_invariants().unwrap();
    }

    #[test]
    fn mixed_load_meets_online_slo() {
        let mut e = engine(SchedulerKind::Echo);
        // 20 online requests over 60 s.
        for i in 0..20 {
            let id = e.store.fresh_id();
            e.submit_online(Request::new(
                id,
                TaskClass::Online,
                i as f64 * 3.0,
                PromptSpec::sim(300, None),
                16,
            ));
        }
        // Offline backlog.
        let mut rng = Rng::new(5);
        let mut store2 = crate::core::RequestStore::new();
        let _ = &mut store2;
        for _ in 0..30 {
            let id = e.store.fresh_id();
            let r = Request::new(
                id,
                TaskClass::Offline,
                0.0,
                PromptSpec::sim(1000 + (rng.range_usize(0, 500)), None),
                32,
            );
            e.submit_offline(r);
        }
        e.run().unwrap();
        assert_eq!(e.metrics.online_completed, 20);
        assert_eq!(e.metrics.offline_completed, 30);
        let (a_ttft, a_tpot) = e.metrics.slo_attainment(&e.cfg.slo);
        assert!(a_ttft >= 0.9, "ttft attainment {a_ttft}");
        assert!(a_tpot >= 0.9, "tpot attainment {a_tpot}");
        e.kv.check_invariants().unwrap();
    }

    #[test]
    fn online_burst_preempts_offline_and_both_finish() {
        let mut e = engine(SchedulerKind::Echo);
        e.cfg.cache.capacity_tokens = 20_000;
        // Rebuild with small memory:
        let mut e = {
            let mut cfg = SystemConfig::a100_llama8b();
            cfg.scheduler.kind = SchedulerKind::Echo;
            cfg.cache.capacity_tokens = 20_000;
            let b = SimBackend::new(crate::estimator::TimeModel::new(cfg.time_model), 1, 0.0);
            Engine::new(cfg, b)
        };
        // Big offline requests that fill memory.
        for _ in 0..4 {
            let id = e.store.fresh_id();
            e.submit_offline(Request::new(
                id,
                TaskClass::Offline,
                0.0,
                PromptSpec::sim(4000, None),
                64,
            ));
        }
        // Online burst at t=2.
        for i in 0..10 {
            let id = e.store.fresh_id();
            e.submit_online(Request::new(
                id,
                TaskClass::Online,
                2.0 + i as f64 * 0.01,
                PromptSpec::sim(800, None),
                24,
            ));
        }
        e.run().unwrap();
        assert_eq!(e.metrics.online_completed, 10);
        assert_eq!(e.metrics.offline_completed, 4);
        e.kv.check_invariants().unwrap();
    }

    #[test]
    fn steady_state_step_reuses_scratch() {
        let mut e = engine(SchedulerKind::Echo);
        for _ in 0..6 {
            let id = e.store.fresh_id();
            e.submit_offline(Request::new(
                id,
                TaskClass::Offline,
                0.0,
                PromptSpec::sim(200, None),
                256,
            ));
        }
        // Warm up: admissions + prefill; scratch capacities peak here.
        for _ in 0..40 {
            assert!(e.step().unwrap());
        }
        let grows = e.step_alloc_growth();
        for _ in 0..100 {
            assert!(e.step().unwrap());
        }
        assert_eq!(
            e.step_alloc_growth(),
            grows,
            "steady-state steps must not grow the recycled step buffers"
        );
    }

    #[test]
    fn cancel_future_arrival_uses_sorted_lookup() {
        let mut e = engine(SchedulerKind::Echo);
        let ids: Vec<_> = (0..3)
            .map(|i| {
                let id = e.store.fresh_id();
                e.submit_online(Request::new(
                    id,
                    TaskClass::Online,
                    5.0 + i as f64,
                    PromptSpec::sim(100, None),
                    4,
                ));
                id
            })
            .collect();
        assert!(e.cancel(ids[1]));
        assert!(!e.cancel(ids[1]), "already terminal");
        assert_eq!(e.backlog_online(), 2);
        e.run().unwrap();
        assert_eq!(e.metrics.online_completed, 2);
        assert_eq!(e.metrics.cancelled_online, 1);
    }

    #[test]
    fn cancel_in_admission_queue_uses_membership_check() {
        let mut cfg = SystemConfig::a100_llama8b();
        cfg.scheduler.kind = SchedulerKind::Echo;
        cfg.scheduler.max_batch = 1;
        let backend = SimBackend::new(
            crate::estimator::TimeModel::new(cfg.time_model),
            1,
            0.0,
        );
        let mut e = Engine::new(cfg, backend);
        let first = e.store.fresh_id();
        e.submit_online(Request::new(
            first,
            TaskClass::Online,
            0.0,
            PromptSpec::sim(100, None),
            4,
        ));
        let second = e.store.fresh_id();
        e.submit_online(Request::new(
            second,
            TaskClass::Online,
            0.0,
            PromptSpec::sim(100, None),
            4,
        ));
        // One step: `first` admitted (max_batch 1), `second` stays queued.
        e.step().unwrap();
        assert_eq!(e.store.get(second).state, crate::core::ReqState::Queued);
        assert!(e.cancel(second));
        assert_eq!(e.backlog_online(), 0);
        e.run().unwrap();
        assert_eq!(e.metrics.online_completed, 1);
        assert_eq!(e.metrics.cancelled_online, 1);
    }

    #[test]
    fn echo_beats_bs_e_on_shared_offline_throughput() {
        // The headline mechanism: with a shared-prefix offline workload and
        // a bursty online load, Echo (KV-aware + task-aware cache) should
        // need fewer recomputed prefill tokens than BS+E (FCFS + LRU).
        let run = |kind: SchedulerKind| {
            let mut cfg = SystemConfig::a100_llama8b();
            cfg.scheduler.kind = kind;
            // Tight memory so eviction pressure is real.
            cfg.cache.capacity_tokens = 2_000;
            cfg.scheduler.max_batch = 16;
            let b = SimBackend::new(crate::estimator::TimeModel::new(cfg.time_model), 1, 0.0);
            let mut e = Engine::new(cfg, b);
            let mut rng = Rng::new(11);
            let spec = DatasetSpec::loogle_qa_short().scaled(0.1); // ~800 tok prompts
            let batch =
                synthesize(&spec, 100, TaskClass::Offline, 0.0, &mut e.store, &mut rng);
            // Shuffle submission order: FCFS no longer follows groups, so
            // prefix locality must be *recovered* by the KV-aware selector.
            let mut ids = batch.ids.clone();
            rng.shuffle(&mut ids);
            for &id in &ids {
                e.register_offline(id);
            }
            // Sustained online churn that flushes an LRU cache.
            for i in 0..130 {
                let id = e.store.fresh_id();
                e.submit_online(Request::new(
                    id,
                    TaskClass::Online,
                    1.0 + i as f64 * 0.3,
                    PromptSpec::sim(300, None),
                    16,
                ));
            }
            e.run().unwrap();
            assert_eq!(e.metrics.offline_completed, 100);
            (
                e.metrics.prefill_tokens_computed,
                e.metrics.offline_throughput(),
                e.kv.stats.hit_ratio(),
            )
        };
        let (bse_computed, _bse_thr, bse_hit) = run(SchedulerKind::BsE);
        let (echo_computed, _echo_thr, echo_hit) = run(SchedulerKind::Echo);
        assert!(
            echo_computed < bse_computed,
            "echo recomputes less: {echo_computed} vs {bse_computed}"
        );
        assert!(
            echo_hit > bse_hit,
            "echo hit ratio {echo_hit} vs bs+e {bse_hit}"
        );
    }
}

//! [`Serve`] over a replica fleet: router dispatch, offline work-stealing,
//! and tidal autoscaling behind the same trait as a bare engine. One
//! `pump` = one router/digest sync quantum; tickets follow their jobs
//! across work-steal migrations (see `cluster::JobSpec::ticket`), so
//! streaming and cancellation keep working while work moves.

use std::collections::{BTreeMap, VecDeque};

use crate::cluster::{ClusterConfig, ClusterSim, JobSpec, OnlineJob};
use crate::core::{ReqState, TaskClass};
use crate::faults::{CancelReason, ServeError};
use crate::metrics::Metrics;

use super::{
    AdmissionVerdict, Cursor, EventSink, JournalConfig, MetricsView, Serve, SessionJournal,
    SubmitSpec, Ticket, TicketId, TokenEvent,
};

pub struct ClusterServe {
    pub sim: ClusterSim,
    clock: f64,
    begun: bool,
    next_ticket: TicketId,
    /// Online submissions not yet due, sorted ascending by arrival
    /// (stable: equal arrivals keep submission order, like the batch
    /// replay's sorted slice).
    pending_online: VecDeque<(TicketId, OnlineJob)>,
    cursors: BTreeMap<TicketId, Cursor>,
    /// Placement each tracked ticket last streamed from. A move
    /// (work-steal migration) RESTARTS that ticket's stream: recompute
    /// semantics regenerate the output from scratch on the thief, so
    /// splicing the two incarnations at the old cursor position would mix
    /// token values/timestamps from different generations.
    last_place: BTreeMap<TicketId, (usize, crate::core::RequestId)>,
    /// Cancellation events queued for the next pump.
    pending_events: Vec<TokenEvent>,
    cancelled: usize,
    /// Verdict of the most recent `submit` (SLO-guard backpressure): the
    /// wire layer reads this to put `verdict`/`retry_after` on the ack.
    last_verdict: AdmissionVerdict,
    /// Durable-session journal (PR 10); `None` = disarmed (zero cost).
    journal: Option<SessionJournal>,
}

impl ClusterServe {
    pub fn new(cfg: ClusterConfig) -> Self {
        ClusterServe {
            sim: ClusterSim::new(cfg),
            clock: 0.0,
            begun: false,
            next_ticket: 0,
            pending_online: VecDeque::new(),
            cursors: BTreeMap::new(),
            last_place: BTreeMap::new(),
            pending_events: Vec::new(),
            cancelled: 0,
            last_verdict: AdmissionVerdict::Accept,
            journal: None,
        }
    }

    /// Cluster clock (quantum-aligned virtual seconds).
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Submit a batch of offline job specs through the trait (backlog
    /// order preserved); returns the tickets. The one copy of the loop
    /// every batch driver (CLI, figures, examples) repeats.
    pub fn submit_offline_jobs(
        &mut self,
        jobs: impl IntoIterator<Item = JobSpec>,
    ) -> anyhow::Result<Vec<Ticket>> {
        let mut out = Vec::new();
        for job in jobs {
            out.push(self.submit(SubmitSpec::offline(job.prompt, job.max_new_tokens))?);
        }
        Ok(out)
    }

    /// Submit online jobs (trace replay) with their pinned arrivals.
    pub fn submit_online_jobs<'a>(
        &mut self,
        jobs: impl IntoIterator<Item = &'a OnlineJob>,
    ) -> anyhow::Result<Vec<Ticket>> {
        let mut out = Vec::new();
        for job in jobs {
            let spec = SubmitSpec::online(job.prompt.clone(), job.max_new_tokens);
            out.push(self.submit(spec.at(job.at))?);
        }
        Ok(out)
    }

    /// Any work left anywhere in the fleet?
    fn busy(&self) -> bool {
        !self.pending_online.is_empty()
            || !self.sim.backlog.is_empty()
            || self.sim.replicas.iter().any(|r| !r.is_idle())
    }

    /// Advance exactly one quantum ending at `t_end`.
    fn pump_to(&mut self, t_end: f64, sink: &mut dyn EventSink) -> anyhow::Result<bool> {
        if !self.begun {
            self.sim.begin();
            self.begun = true;
        }
        let t = self.clock;
        // 1. dispatch online submissions due in (t, t_end]
        while matches!(self.pending_online.front(), Some((_, job)) if job.at <= t_end) {
            // lint: allow-unwrap(the matches! loop condition saw Some(front))
            let (ticket, job) = self.pending_online.pop_front().expect("checked non-empty");
            if let Some((rep, rid)) = self.sim.dispatch_online(&job) {
                self.sim.record_ticket(ticket, rep, rid);
            }
        }
        // 2. advance the fleet
        self.sim.advance_replicas(t, t_end)?;
        // 2b. reject unschedulable work (fleet edition of the threaded
        // server's rejection): a replica whose clock stalled short of the
        // quantum end while holding live queued/preempted work hit
        // `Engine::step`'s "nothing can ever be scheduled" exit — the
        // fleet is homogeneous, so no other replica could take it either.
        // Only ticketed requests are rejected; batch replays keep the
        // engine's warn-and-idle behavior.
        let mut stuck: Vec<TicketId> = Vec::new();
        for rep in &self.sim.replicas {
            if rep.engine.clock >= t_end {
                continue;
            }
            // A crashed replica also stops short of the quantum end, but
            // its queue is not stuck — recovery re-dispatches it at the
            // quantum boundary. Judging the corpse here would cancel work
            // that is about to be salvaged.
            if self.sim.failed_pending(rep.id) {
                continue;
            }
            for r in rep.engine.live_requests() {
                if matches!(r.state, ReqState::Queued | ReqState::Preempted) {
                    if let Some(ticket) = self.sim.ticket_at(rep.id, r.id) {
                        stuck.push(ticket);
                    }
                }
            }
        }
        for ticket in stuck {
            let _ = self.cancel_with(ticket, CancelReason::Unschedulable);
        }
        // 2c. overload shedding (off under the default policy).
        self.shed_overload(t_end);
        // 3. deliver events (before post-quantum bookkeeping: a drained
        // replica may retire there, dropping its store)
        let wants = sink.wants_events();
        // Live durable tickets force event materialization even under a
        // NullSink: their replay buffers must see every event. The armed
        // journal with no durable tickets costs exactly this one check.
        let journal_live = self.journal.as_ref().is_some_and(|j| !j.is_empty());
        let materialize = wants || journal_live;
        let mut evs = std::mem::take(&mut self.pending_events);
        if !materialize {
            evs.clear();
        }
        let mut done: Vec<TicketId> = Vec::new();
        for (&ticket, cur) in self.cursors.iter_mut() {
            let Some((rep_id, rid)) = self.sim.ticket_location(ticket) else {
                continue; // still in the backlog
            };
            let Some(rep) = self.sim.replica(rep_id) else {
                continue;
            };
            let Some(r) = rep.engine.store.try_get(rid) else {
                continue;
            };
            // A work-steal moved the job since the last drain: the new
            // incarnation regenerates from scratch, so restart the stream
            // (fresh cursor) with a Preempted marker rather than splicing
            // token indices across incarnations.
            let place = (rep_id, rid);
            match self.last_place.get(&ticket) {
                Some(&p) if p == place => {}
                Some(_) => {
                    *cur = Cursor::default();
                    if materialize {
                        evs.push(TokenEvent::Preempted { ticket, at: t_end });
                    }
                    self.last_place.insert(ticket, place);
                }
                None => {
                    self.last_place.insert(ticket, place);
                }
            }
            let terminal = if materialize {
                cur.drain(ticket, r, t_end, &mut evs)
            } else {
                cur.fast_forward(r)
            };
            if terminal {
                done.push(ticket);
            }
        }
        for ticket in done {
            self.cursors.remove(&ticket);
            self.last_place.remove(&ticket);
            self.sim.forget_ticket(ticket);
        }
        // 3b. journal capture: durable tickets' events enter their replay
        // rings here, in the single-threaded coordinator path, so
        // journal-armed runs stay bit-exact across --threads.
        if let Some(j) = self.journal.as_mut() {
            if journal_live {
                for ev in &evs {
                    j.append(ev, t_end);
                }
            }
            j.expire(t_end);
        }
        // 4. post-quantum bookkeeping (digests, retirement, stealing,
        // scaling)
        self.sim.finish_quantum(t_end);
        self.clock = t_end;
        if wants {
            for ev in &evs {
                sink.on_event(ev);
            }
        }
        Ok(self.busy())
    }

    /// Queue the Cancelled event. `pre_placement` cancels (pending online /
    /// shared backlog) are counted here; replica-placed cancels are already
    /// counted by that engine's metrics (`Engine::cancel`), so counting
    /// them again would double-book the snapshot.
    fn emit_cancel(&mut self, ticket: TicketId, reason: CancelReason, pre_placement: bool) {
        self.cursors.remove(&ticket);
        self.last_place.remove(&ticket);
        self.pending_events.push(TokenEvent::Cancelled {
            ticket,
            at: self.clock,
            reason,
        });
        if pre_placement {
            self.cancelled += 1;
        }
    }

    /// Cancel with a typed reason (the trait's `cancel` is the
    /// client-initiated special case). Same three-tier search: pending
    /// online, shared backlog, placed on a replica.
    fn cancel_with(&mut self, ticket: TicketId, reason: CancelReason) -> bool {
        // Not yet dispatched online?
        if let Some(pos) = self.pending_online.iter().position(|&(t, _)| t == ticket) {
            let _ = self.pending_online.remove(pos);
            self.emit_cancel(ticket, reason, true);
            return true;
        }
        // Still in the shared offline backlog?
        if let Some(pos) = self.sim.backlog.iter().position(|j| j.ticket == Some(ticket)) {
            let _ = self.sim.backlog.remove(pos);
            self.emit_cancel(ticket, reason, true);
            return true;
        }
        // Placed on a replica (pooled, running, or preempted there).
        let Some((rep_id, rid)) = self.sim.ticket_location(ticket) else {
            return false;
        };
        let Some(pos) = self.sim.replicas.iter().position(|r| r.id == rep_id) else {
            return false; // replica retired; ticket already terminal
        };
        if self.sim.replicas[pos].engine.cancel(rid) {
            self.sim.forget_ticket(ticket);
            self.emit_cancel(ticket, reason, false);
            true
        } else {
            false
        }
    }

    /// THE offline-admission decision (SLO guard, PR 9): the single place
    /// a new offline submission is judged. Maps the guard's current
    /// brownout decision to a typed verdict — `Retry` at `ShedNewOffline`
    /// (transient: back off `retry_after` seconds and resubmit), `Shed`
    /// under `Emergency` (the fleet is actively preempting offline work).
    /// Disarmed or below `ShedNewOffline` every submission is accepted;
    /// backlog *overflow* trimming after acceptance stays with
    /// [`Self::shed_overload`], driven by the same static `ShedPolicy` as
    /// PR 7 — so exactly one controller state decides front-door shedding
    /// and exactly one policy decides overflow shedding.
    fn offline_admission_verdict(&self) -> AdmissionVerdict {
        let d = self.sim.guard_decision();
        if d.emergency {
            AdmissionVerdict::Shed {
                after: d.retry_after,
            }
        } else if d.shed_new {
            AdmissionVerdict::Retry {
                after: d.retry_after,
            }
        } else {
            AdmissionVerdict::Accept
        }
    }

    /// Overload shedding per the cluster's [`crate::faults::ShedPolicy`].
    /// Offline work is revocable by contract (§2's hybrid bargain), so the
    /// newest backlog excess goes first; online requests are only shed once
    /// they have waited past `online_grace`× the SLO TTFT in a queue — at
    /// that point the SLO is unattainable and holding the slot just starves
    /// the requests behind it. Both knobs default to off.
    ///
    /// Division of labor with the SLO guard (PR 9): this trims *accepted*
    /// backlog against static limits; the guard rejects *new* offline work
    /// at the front door ([`Self::offline_admission_verdict`]) and
    /// pauses/preempts *placed* work via the scheduler actuators. Each
    /// shed path has exactly one owner, so the two policies never fight
    /// over the same request.
    fn shed_overload(&mut self, t_end: f64) {
        let shed = self.sim.cfg.shed;
        while self.sim.backlog.len() > shed.max_backlog {
            let Some(job) = self.sim.backlog.pop_back() else {
                break;
            };
            self.sim.fault_stats.shed_offline += 1;
            if let Some(ticket) = job.ticket {
                self.emit_cancel(ticket, CancelReason::ShedOverload, true);
            }
        }
        if !shed.online_grace.is_finite() {
            return;
        }
        let deadline = self.sim.cfg.base.slo.ttft * shed.online_grace;
        let mut expired: Vec<TicketId> = Vec::new();
        for rep in &self.sim.replicas {
            if self.sim.failed_pending(rep.id) {
                continue; // about to be recovered, not stuck in a queue
            }
            for r in rep.engine.live_requests() {
                if r.class == TaskClass::Online
                    && r.state == ReqState::Queued
                    && t_end - r.arrival > deadline
                {
                    if let Some(ticket) = self.sim.ticket_at(rep.id, r.id) {
                        expired.push(ticket);
                    }
                }
            }
        }
        for ticket in expired {
            if self.cancel_with(ticket, CancelReason::DeadlineExpired) {
                self.sim.fault_stats.shed_online += 1;
            }
        }
    }

    /// Fleet-progress signature for the drain stall detector: any change
    /// means the deployment is still moving (executing, completing,
    /// cancelling, or shuffling queues). The guard's `pause_ticks` counter
    /// is part of the signature: a backlog deliberately held back by the
    /// brownout ladder is *paused by policy*, not stuck — the controller
    /// is guaranteed to ratchet back to `Normal` once the online burst
    /// leaves the measurement window (empty windows read as vacuous
    /// attainment), so counting those ticks as progress keeps the stall
    /// detector from cancelling work the guard is about to release.
    fn progress_signature(&self) -> (usize, usize, usize, usize, usize, usize, u64) {
        let m = self.sim.all_metrics();
        (
            m.iterations,
            m.online_completed + m.offline_completed,
            self.sim.backlog.len(),
            self.pending_online.len(),
            self.cursors.len(),
            self.cancelled,
            self.sim.guard_stats().pause_ticks,
        )
    }
}

impl Serve for ClusterServe {
    fn submit(&mut self, spec: SubmitSpec) -> anyhow::Result<Ticket> {
        // Idempotent replay (PR 10): a previously seen key returns the
        // ticket it minted instead of admitting a second copy. Only
        // *accepted* submits register (below), so retrying a backpressured
        // submit with the same key gets a fresh admission decision.
        if let (Some(key), Some(j)) = (spec.idem_key, self.journal.as_mut()) {
            if let Some(t) = j.lookup(key) {
                j.stats.replayed_submits += 1;
                self.last_verdict = AdmissionVerdict::Accept;
                return Ok(t);
            }
        }
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let class = spec.slo.task_class();
        let arrival = spec.arrival.unwrap_or(self.clock);
        self.last_verdict = AdmissionVerdict::Accept;
        match class {
            TaskClass::Online => {
                let job = OnlineJob {
                    at: arrival,
                    prompt: spec.prompt,
                    max_new_tokens: spec.max_new_tokens,
                };
                let pos = self
                    .pending_online
                    .iter()
                    .take_while(|(_, j)| j.at <= job.at)
                    .count();
                self.pending_online.insert(pos, (ticket, job));
            }
            TaskClass::Offline => {
                // SLO-guard backpressure: a browned-out fleet rejects new
                // offline work with a typed verdict instead of queueing it
                // behind a paused backlog. The ticket is still issued —
                // its immediate terminal `Cancelled(Shed)` event is the
                // in-band signal, and the verdict (with `retry_after`)
                // rides the wire ack.
                let verdict = self.offline_admission_verdict();
                self.last_verdict = verdict;
                if !verdict.is_accept() {
                    if let Some(guard) = self.sim.guard_mut() {
                        match verdict {
                            AdmissionVerdict::Retry { .. } => guard.stats.retry_submits += 1,
                            AdmissionVerdict::Shed { .. } => guard.stats.shed_submits += 1,
                            AdmissionVerdict::Accept => {}
                        }
                    }
                    self.sim.fault_stats.shed_offline += 1;
                    self.emit_cancel(ticket, CancelReason::Shed, true);
                    return Ok(Ticket {
                        id: ticket,
                        class,
                        submitted_at: arrival,
                    });
                }
                self.sim.backlog.push_back(JobSpec {
                    prompt: spec.prompt,
                    max_new_tokens: spec.max_new_tokens,
                    ticket: Some(ticket),
                });
            }
        }
        self.cursors.insert(ticket, Cursor::default());
        let issued = Ticket {
            id: ticket,
            class,
            submitted_at: arrival,
        };
        if let (Some(key), Some(j)) = (spec.idem_key, self.journal.as_mut()) {
            j.register(issued, key);
        }
        Ok(issued)
    }

    fn last_verdict(&self) -> AdmissionVerdict {
        self.last_verdict
    }

    fn cancel(&mut self, ticket: TicketId) -> bool {
        self.cancel_with(ticket, CancelReason::Client)
    }

    fn pump(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<bool> {
        let t_end = self.clock + self.sim.cfg.sync_dt;
        self.pump_to(t_end, sink)
    }

    fn drain(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        // Stall detection: `busy()` can stay true forever when something
        // holds live work that never advances (a scheduling bug, or a
        // pathological fault plan). Watch a fleet-progress signature on the
        // virtual clock; after `stall_after` sim-seconds with zero change,
        // terminate the remaining tickets as `Stalled` instead of spinning
        // to the iteration backstop. Only armed while a ticket exists to
        // judge — a truly wedged ticketless fleet falls through to the
        // typed backstop error below.
        let dt = self.sim.cfg.sync_dt.max(1e-9);
        let stall_pumps = (self.sim.cfg.shed.stall_after / dt).ceil().max(1.0) as usize;
        let mut last_sig = self.progress_signature();
        let mut stalled = 0usize;
        const MAX_PUMPS: usize = 10_000_000;
        // Generous backstop mirroring Engine::max_iterations.
        for _ in 0..MAX_PUMPS {
            if !self.pump(sink)? {
                return Ok(());
            }
            let sig = self.progress_signature();
            if sig == last_sig {
                stalled += 1;
            } else {
                stalled = 0;
                last_sig = sig;
            }
            if stalled >= stall_pumps {
                let wedged: Vec<TicketId> = self.cursors.keys().copied().collect();
                if wedged.is_empty() {
                    return Err(ServeError::QuantumBackstop {
                        pumps: stalled as u64,
                    }
                    .into());
                }
                log::warn!(
                    "fleet made no progress for {:.1} sim-seconds; cancelling {} stalled ticket(s)",
                    stalled as f64 * dt,
                    wedged.len()
                );
                for ticket in wedged {
                    if self.cancel_with(ticket, CancelReason::Stalled) {
                        self.sim.fault_stats.stalled_cancels += 1;
                    }
                }
                stalled = 0;
                last_sig = self.progress_signature();
                continue;
            }
            // Idle fast-forward (the engine's idle-jump, fleet edition):
            // when every replica is drained and the backlog is empty, only
            // future pinned arrivals remain — jump to the next one on the
            // quantum grid instead of grinding empty sync quanta.
            if self.sim.backlog.is_empty() && self.sim.replicas.iter().all(|r| r.is_idle()) {
                if let Some((_, job)) = self.pending_online.front() {
                    let dt = self.sim.cfg.sync_dt;
                    if job.at > self.clock + dt {
                        let quanta = ((job.at - self.clock) / dt).floor();
                        self.clock += (quanta - 1.0).max(0.0) * dt;
                    }
                }
            }
        }
        Err(ServeError::QuantumBackstop {
            pumps: MAX_PUMPS as u64,
        }
        .into())
    }

    fn run_until(&mut self, deadline: f64, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        while self.clock < deadline {
            let t_end = (self.clock + self.sim.cfg.sync_dt).min(deadline);
            self.pump_to(t_end, sink)?;
        }
        Ok(())
    }

    fn snapshot(&self) -> MetricsView {
        let m: Metrics = self.sim.all_metrics();
        let queued: usize = self
            .sim
            .replicas
            .iter()
            .map(|r| r.engine.backlog_online())
            .sum::<usize>()
            + self.pending_online.len();
        let pooled: usize = self
            .sim
            .replicas
            .iter()
            .map(|r| r.engine.pool.len())
            .sum::<usize>()
            + self.sim.backlog.len();
        let running: usize = self
            .sim
            .replicas
            .iter()
            .map(|r| {
                r.engine
                    .live_requests()
                    .filter(|q| q.state == crate::core::ReqState::Running)
                    .count()
            })
            .sum();
        let lookups: u64 = self
            .sim
            .replicas
            .iter()
            .map(|r| r.engine.kv.stats.lookup_blocks)
            .sum();
        let hits: u64 = self
            .sim
            .replicas
            .iter()
            .map(|r| r.engine.kv.stats.hit_blocks)
            .sum();
        MetricsView {
            deployment: "cluster",
            clock: self.clock,
            queued_online: queued,
            pooled_offline: pooled,
            running,
            online_completed: m.online_completed,
            offline_completed: m.offline_completed,
            cancelled: self.cancelled + m.cancelled_online + m.cancelled_offline,
            preemptions: m.preemptions,
            busy_time: m.busy_time,
            online_throughput: m.online_throughput(),
            offline_throughput: m.offline_throughput(),
            hit_ratio: if lookups == 0 {
                0.0
            } else {
                hits as f64 / lookups as f64
            },
            replicas: self.sim.active_replicas(),
            latency: m.latency_view(),
            journal: self
                .journal
                .as_ref()
                .map(|j| j.stats.clone())
                .unwrap_or_default(),
        }
    }

    fn arm_journal(&mut self, cfg: JournalConfig) -> bool {
        if self.journal.is_none() {
            self.journal = Some(SessionJournal::new(cfg));
        }
        true
    }

    fn journal(&self) -> Option<&SessionJournal> {
        self.journal.as_ref()
    }

    fn journal_mut(&mut self) -> Option<&mut SessionJournal> {
        self.journal.as_mut()
    }

    fn ack(&mut self, ticket: TicketId) -> bool {
        self.journal.as_mut().is_some_and(|j| j.ack(ticket))
    }

    fn obs(&self) -> crate::utils::json::Json {
        let m: Metrics = self.sim.all_metrics();
        crate::obs::summary(&m, &self.sim.trace_tracks())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::core::PromptSpec;

    fn small() -> ClusterServe {
        let mut base = SystemConfig::a100_llama8b();
        base.cache.capacity_tokens = 30_000;
        base.scheduler.max_batch = 16;
        let mut cc = ClusterConfig::new(base, 2);
        cc.jitter = 0.0;
        ClusterServe::new(cc)
    }

    #[test]
    fn fleet_serves_and_streams_through_the_trait() {
        let mut s = small();
        let mut tickets = Vec::new();
        for i in 0..6 {
            let spec = SubmitSpec::online(PromptSpec::sim(200 + i * 20, None), 4);
            let t = s.submit(spec.at(0.5 + i as f64)).unwrap();
            tickets.push(t.id);
        }
        for _ in 0..8 {
            s.submit(SubmitSpec::offline(PromptSpec::sim(400, None), 8)).unwrap();
        }
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        let finished: Vec<TicketId> = evs
            .iter()
            .filter(|e| matches!(e, TokenEvent::Finished { .. }))
            .map(|e| e.ticket())
            .collect();
        assert_eq!(finished.len(), 14, "every ticket finishes: {evs:?}");
        for t in tickets {
            assert!(finished.contains(&t));
        }
        let snap = s.snapshot();
        assert_eq!(snap.online_completed, 6);
        assert_eq!(snap.offline_completed, 8);
        for rep in &s.sim.replicas {
            rep.engine.kv.check_invariants().unwrap();
        }
    }

    #[test]
    fn unschedulable_ticket_is_rejected() {
        // A job larger than a replica's whole KV capacity can never be
        // scheduled anywhere in a homogeneous fleet; the front door must
        // reject it with a terminal event instead of grinding quanta.
        let mut s = small(); // 30k-token caches
        let t = s.submit(SubmitSpec::offline(PromptSpec::sim(40_000, None), 8)).unwrap();
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        assert!(
            evs.iter()
                .any(|e| matches!(e, TokenEvent::Cancelled { ticket, .. } if *ticket == t.id)),
            "unschedulable job must be rejected: {evs:?}"
        );
        assert_eq!(s.snapshot().offline_completed, 0);
        assert_eq!(s.snapshot().cancelled, 1);
    }

    #[test]
    fn cancel_works_in_backlog_and_on_replicas() {
        let mut s = small();
        // Backlog cancel: second offline job withdrawn before placement.
        let a = s.submit(SubmitSpec::offline(PromptSpec::sim(300, None), 8)).unwrap();
        let b = s.submit(SubmitSpec::offline(PromptSpec::sim(300, None), 8)).unwrap();
        assert!(s.cancel(b.id), "backlog cancel");
        // Pending-online cancel.
        let c = s.submit(SubmitSpec::online(PromptSpec::sim(100, None), 4).at(50.0)).unwrap();
        assert!(s.cancel(c.id), "pending-online cancel");
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.run_until(60.0, &mut evs).unwrap();
        let cancelled: Vec<TicketId> = evs
            .iter()
            .filter(|e| matches!(e, TokenEvent::Cancelled { .. }))
            .map(|e| e.ticket())
            .collect();
        assert_eq!(cancelled, vec![b.id, c.id]);
        assert!(evs
            .iter()
            .any(|e| matches!(e, TokenEvent::Finished { ticket, .. } if *ticket == a.id)));
        assert_eq!(s.snapshot().offline_completed, 1);
        assert_eq!(s.snapshot().cancelled, 2);
    }

    #[test]
    fn replica_crash_mid_serve_finishes_every_ticket() {
        use crate::faults::{FaultEvent, FaultPlan};
        let mut base = SystemConfig::a100_llama8b();
        base.cache.capacity_tokens = 30_000;
        base.scheduler.max_batch = 16;
        let mut cc = ClusterConfig::new(base, 2);
        cc.jitter = 0.0;
        cc.faults = FaultPlan {
            events: vec![FaultEvent::Crash {
                at: 2.0,
                replica: 0,
            }],
            seed: 9,
        };
        let mut s = ClusterServe::new(cc);
        let mut tickets = Vec::new();
        for i in 0..6 {
            let spec = SubmitSpec::online(PromptSpec::sim(200 + i * 20, None), 4);
            tickets.push(s.submit(spec.at(0.5 + i as f64)).unwrap().id);
        }
        for _ in 0..8 {
            let t = s.submit(SubmitSpec::offline(PromptSpec::sim(400, None), 8)).unwrap();
            tickets.push(t.id);
        }
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        let finished: Vec<TicketId> = evs
            .iter()
            .filter(|e| matches!(e, TokenEvent::Finished { .. }))
            .map(|e| e.ticket())
            .collect();
        for t in &tickets {
            assert!(finished.contains(t), "ticket {t} must finish: {evs:?}");
        }
        assert_eq!(s.sim.fault_stats.crashes, 1);
        for rep in &s.sim.replicas {
            rep.engine.kv.check_invariants().unwrap();
        }
    }

    #[test]
    fn guard_front_door_rejects_offline_with_typed_backpressure() {
        use crate::slo::{BrownoutLevel, SloGuardConfig};
        // An unattainable SLO climbs the ladder; once the fleet is at
        // ShedNewOffline or worse, new offline submits must get a typed
        // non-accept verdict, an immediate terminal Cancelled(Shed) event,
        // and a positive retry_after hint.
        let mut base = SystemConfig::a100_llama8b();
        base.cache.capacity_tokens = 30_000;
        base.scheduler.max_batch = 16;
        base.slo = crate::core::Slo::new(1e-6, 1e-9);
        let mut cc = ClusterConfig::new(base, 2);
        cc.jitter = 0.0;
        cc.guard = Some(SloGuardConfig::default());
        let mut s = ClusterServe::new(cc);
        for i in 0..12 {
            let spec = SubmitSpec::online(PromptSpec::sim(200, None), 4);
            s.submit(spec.at(0.2 + 0.5 * i as f64)).unwrap();
        }
        assert!(s.last_verdict().is_accept(), "online is never backpressured");
        let mut evs: Vec<TokenEvent> = Vec::new();
        let mut level = BrownoutLevel::Normal;
        for _ in 0..200 {
            s.pump(&mut evs).unwrap();
            level = s.sim.guard_decision().level;
            if level >= BrownoutLevel::ShedNewOffline {
                break;
            }
        }
        assert!(
            level >= BrownoutLevel::ShedNewOffline,
            "misses must climb the ladder (got {level:?})"
        );
        let t = s
            .submit(SubmitSpec::offline(PromptSpec::sim(300, None), 8))
            .unwrap();
        let v = s.last_verdict();
        assert!(!v.is_accept(), "browned-out fleet must backpressure: {v:?}");
        let after = v.retry_after().unwrap();
        assert!(after > 0.0, "retry hint must be positive: {after}");
        s.pump(&mut evs).unwrap();
        assert!(
            evs.iter().any(|e| matches!(
                e,
                TokenEvent::Cancelled {
                    ticket,
                    reason: CancelReason::Shed,
                    ..
                } if *ticket == t.id
            )),
            "rejected ticket must be terminal with the typed reason: {evs:?}"
        );
        let stats = s.sim.guard_stats();
        assert_eq!(stats.retry_submits + stats.shed_submits, 1, "{stats:?}");
        assert_eq!(s.sim.fault_stats.shed_offline, 1);
    }

    #[test]
    fn overload_shedding_emits_typed_reasons() {
        use crate::faults::ShedPolicy;
        let mut base = SystemConfig::a100_llama8b();
        base.cache.capacity_tokens = 30_000;
        base.scheduler.max_batch = 16;
        let mut cc = ClusterConfig::new(base, 2);
        cc.jitter = 0.0;
        // One job per pool at the flood, so the backlog length is exact.
        cc.steal_low_water = 1;
        cc.steal_batch = 1;
        cc.shed = ShedPolicy::aggressive(4, f64::INFINITY);
        let mut s = ClusterServe::new(cc);
        for _ in 0..12 {
            s.submit(SubmitSpec::offline(PromptSpec::sim(300, None), 8)).unwrap();
        }
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        // 12 submitted - 2 flooded to pools - 4 kept in backlog = 6 shed
        // (newest first), all with the typed ShedOverload reason.
        let shed = evs
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    TokenEvent::Cancelled {
                        reason: CancelReason::ShedOverload,
                        ..
                    }
                )
            })
            .count();
        assert_eq!(shed, 6, "{evs:?}");
        assert_eq!(s.sim.fault_stats.shed_offline, 6);
        assert_eq!(s.snapshot().offline_completed, 6);
    }
}

//! `echo serve`'s wire protocol: line-delimited JSON over std::net (or
//! stdin/stdout), speaking the [`Serve`] trait — so the same client script
//! exercises one engine, the threaded server, or a whole fleet.
//!
//! Grammar (one JSON object per line, one or more reply lines per request;
//! see DESIGN.md "Serving API" for the full table):
//!
//!   {"verb":"submit","class":"online","prompt_len":200,"max_new_tokens":8}
//!       -> {"ok":true,"verb":"submit","ticket":0,"class":"online",
//!           "verdict":"accept",...}
//!          (`verdict` is the SLO-guard admission decision: `"accept"`,
//!          or — offline submits against a browned-out fleet — `"retry"` /
//!          `"shed"`, each adding `"retry_after":<seconds>`; a non-accept
//!          ticket is already terminal and its `cancelled` event carries
//!          reason `"shed"`)
//!   {"verb":"cancel","ticket":0}
//!       -> {"ok":true,"verb":"cancel","ticket":0,"cancelled":true}
//!   {"verb":"stream","ticket":0}
//!       -> {"ok":true,"event":"first_token","ticket":0,"at":...}
//!          ... one line per event, then
//!          {"ok":true,"verb":"stream","done":true,"events":5}
//!   {"verb":"metrics"}
//!       -> {"ok":true,"verb":"metrics","metrics":{...}}
//!          (`metrics.latency` carries streaming-histogram percentiles:
//!          `ttft`/`tpot`/`queue_wait` objects with count/mean/p50/p90/p99
//!          and an `estimator` object adding `bias` — fleet-merged for the
//!          cluster deployment, so the percentiles are true fleet-wide
//!          values)
//!   {"verb":"obs"}
//!       -> {"ok":true,"verb":"obs","obs":{...}}
//!          (observability report: `latency` histogram summaries,
//!          lifecycle `counters`, and `trace` — per-replica ring stats
//!          plus the top recompute-cost requests — when the deployment
//!          holds trace rings)
//!   {"verb":"ack","ticket":0}
//!       -> {"ok":true,"verb":"ack","ticket":0,"acked":true}
//!          (releases a durable ticket's journal entry — replay buffer and
//!          idempotency-key binding; `acked:false` when the ticket is
//!          unknown to the journal or the journal is disarmed)
//!   {"verb":"shutdown"}
//!       -> {"ok":true,"verb":"shutdown"}   (and the server exits)
//!
//! Submit options: `group` + `shared_len` declare a sim shared-prefix
//! group, `tokens` carries real token ids instead of `prompt_len`,
//! `arrival` pins the deployment-clock arrival, and `ttft`/`tpot` attach
//! per-ticket online targets. `stream` without a ticket drains everything.
//!
//! Durable sessions (PR 10): `"key":<u64>` on a submit makes the ticket
//! durable on a journal-armed deployment — a resubmit with the same key
//! returns the existing ticket (the ack adds `"replayed":true`) instead of
//! double-executing. A durable ticket's stream is served from its journal
//! ring: every event line adds `"seq":<n>`, `stream` accepts
//! `"from_seq":<n>` to resume after a disconnect, and the stream summary
//! adds `"next_seq"` (plus `"gap":true` if events before `from_seq` were
//! already evicted from the bounded ring).
//!
//! Malformed lines and unknown verbs get `{"ok":false,"error":...}` replies
//! and never kill the connection.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, ToSocketAddrs};

use crate::core::{PromptSpec, Slo, TaskClass, Token};
use crate::faults::{CancelReason, ServeError};
use crate::utils::json::Json;

use super::{Serve, SloClass, SubmitSpec, TicketId, TokenEvent};

/// Hard cap on one request frame (a line). A line longer than this gets a
/// typed `{"ok":false,...}` reply and closes that connection only — the
/// listener and every other stream stay up, and the oversized bytes are
/// discarded without ever being buffered in full.
pub const MAX_FRAME_BYTES: usize = 64 * 1024;

// ---- frames --------------------------------------------------------------

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum WireRequest {
    Submit(SubmitSpec),
    Cancel {
        ticket: TicketId,
    },
    Stream {
        ticket: Option<TicketId>,
        /// Resume point for a durable ticket's seq-numbered stream
        /// (PR 10); ignored when no ticket is given.
        from_seq: Option<u64>,
    },
    /// Release a durable ticket's journal entry (PR 10).
    Ack {
        ticket: TicketId,
    },
    Metrics,
    Obs,
    Shutdown,
}

/// Parse one request line. Errors are protocol-level strings destined for
/// an `{"ok":false,...}` reply.
pub fn parse_request(line: &str) -> Result<WireRequest, String> {
    let j = Json::parse(line).map_err(|e| format!("parse: {e}"))?;
    let verb = j
        .get("verb")
        .and_then(|v| v.as_str())
        .ok_or_else(|| "missing \"verb\"".to_string())?;
    match verb {
        "submit" => {
            let class = j
                .get("class")
                .and_then(|v| v.as_str())
                .ok_or_else(|| "submit: missing \"class\"".to_string())?;
            let prompt = if let Some(arr) = j.get("tokens").and_then(|v| v.as_arr()) {
                let tokens: Option<Vec<Token>> =
                    arr.iter().map(|t| t.as_u64().map(|x| x as Token)).collect();
                PromptSpec::real(tokens.ok_or_else(|| "submit: non-integer token id".to_string())?)
            } else {
                let len = j
                    .get("prompt_len")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| "submit: missing \"prompt_len\" or \"tokens\"".to_string())?;
                let shared = match (
                    j.get("group").and_then(|v| v.as_u64()),
                    j.get("shared_len").and_then(|v| v.as_usize()),
                ) {
                    (Some(g), Some(s)) => Some((g, s)),
                    (None, None) => None,
                    _ => return Err("submit: \"group\" and \"shared_len\" go together".to_string()),
                };
                PromptSpec::sim(len, shared)
            };
            let max_new_tokens = j
                .get("max_new_tokens")
                .and_then(|v| v.as_usize())
                .unwrap_or(16);
            let slo = match class {
                "online" => {
                    let targets = match (
                        j.get("ttft").and_then(|v| v.as_f64()),
                        j.get("tpot").and_then(|v| v.as_f64()),
                    ) {
                        (Some(ttft), Some(tpot)) => Some(Slo::new(ttft, tpot)),
                        (None, None) => None,
                        _ => return Err("submit: \"ttft\" and \"tpot\" go together".to_string()),
                    };
                    SloClass::Online(targets)
                }
                "offline" => SloClass::Offline,
                other => return Err(format!("submit: unknown class {other:?}")),
            };
            Ok(WireRequest::Submit(SubmitSpec {
                prompt,
                max_new_tokens,
                slo,
                arrival: j.get("arrival").and_then(|v| v.as_f64()),
                idem_key: j.get("key").and_then(|v| v.as_u64()),
            }))
        }
        "cancel" => {
            let ticket = j
                .get("ticket")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| "cancel: missing \"ticket\"".to_string())?;
            Ok(WireRequest::Cancel { ticket })
        }
        "stream" => Ok(WireRequest::Stream {
            ticket: j.get("ticket").and_then(|v| v.as_u64()),
            from_seq: j.get("from_seq").and_then(|v| v.as_u64()),
        }),
        "ack" => {
            let ticket = j
                .get("ticket")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| "ack: missing \"ticket\"".to_string())?;
            Ok(WireRequest::Ack { ticket })
        }
        "metrics" => Ok(WireRequest::Metrics),
        "obs" => Ok(WireRequest::Obs),
        "shutdown" => Ok(WireRequest::Shutdown),
        other => Err(format!("unknown verb {other:?}")),
    }
}

/// Re-encode a request (round-trip property tests and client helpers).
pub fn encode_request(req: &WireRequest) -> Json {
    match req {
        WireRequest::Submit(spec) => {
            let mut j = Json::obj()
                .set("verb", "submit")
                .set(
                    "class",
                    match spec.slo {
                        SloClass::Online(_) => "online",
                        SloClass::Offline => "offline",
                    },
                )
                .set("max_new_tokens", spec.max_new_tokens);
            if let Some(tokens) = &spec.prompt.tokens {
                j = j.set(
                    "tokens",
                    Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                );
            } else {
                j = j.set("prompt_len", spec.prompt.total_len);
                if let Some((g, s)) = spec.prompt.shared_prefix {
                    j = j.set("group", g).set("shared_len", s);
                }
            }
            if let Some(t) = spec.arrival {
                j = j.set("arrival", t);
            }
            if let Some(slo) = spec.slo.targets() {
                j = j.set("ttft", slo.ttft).set("tpot", slo.tpot);
            }
            if let Some(key) = spec.idem_key {
                j = j.set("key", key);
            }
            j
        }
        WireRequest::Cancel { ticket } => {
            Json::obj().set("verb", "cancel").set("ticket", *ticket)
        }
        WireRequest::Stream { ticket, from_seq } => {
            let mut j = Json::obj().set("verb", "stream");
            if let Some(t) = ticket {
                j = j.set("ticket", *t);
            }
            if let Some(s) = from_seq {
                j = j.set("from_seq", *s);
            }
            j
        }
        WireRequest::Ack { ticket } => Json::obj().set("verb", "ack").set("ticket", *ticket),
        WireRequest::Metrics => Json::obj().set("verb", "metrics"),
        WireRequest::Obs => Json::obj().set("verb", "obs"),
        WireRequest::Shutdown => Json::obj().set("verb", "shutdown"),
    }
}

/// Encode an event as a reply line.
pub fn encode_event(ev: &TokenEvent) -> Json {
    let base = Json::obj()
        .set("ok", true)
        .set("event", ev.kind())
        .set("ticket", ev.ticket())
        .set("at", ev.at());
    match ev {
        TokenEvent::FirstToken { token, .. } => match token {
            Some(t) => base.set("token", *t as u64),
            None => base,
        },
        TokenEvent::Token { token, index, .. } => {
            let b = base.set("index", *index);
            match token {
                Some(t) => b.set("token", *t as u64),
                None => b,
            }
        }
        TokenEvent::Preempted { .. } => base,
        TokenEvent::Cancelled { reason, .. } => base.set("reason", reason.as_str()),
        TokenEvent::Finished {
            tokens,
            ttft,
            mean_tpot,
            ..
        } => {
            let mut b = base.set("n_tokens", tokens.len());
            if let Some(t) = ttft {
                b = b.set("ttft", *t);
            }
            if let Some(t) = mean_tpot {
                b = b.set("mean_tpot", *t);
            }
            b
        }
    }
}

/// Decode an event reply line (client side).
pub fn parse_event(j: &Json) -> Option<(String, TicketId, f64)> {
    let kind = j.get("event")?.as_str()?.to_string();
    let ticket = j.get("ticket")?.as_u64()?;
    let at = j.get("at")?.as_f64()?;
    Some((kind, ticket, at))
}

/// Decode the `reason` key of a `cancelled` event reply (client side).
/// Absent on non-cancel events and on replies from pre-PR-7 servers.
pub fn parse_cancel_reason(j: &Json) -> Option<CancelReason> {
    CancelReason::parse(j.get("reason")?.as_str()?)
}

fn err_line(msg: &str) -> String {
    Json::obj().set("ok", false).set("error", msg).to_string()
}

// ---- session -------------------------------------------------------------

/// One client conversation over a [`Serve`] deployment. Pure
/// line-in/lines-out state machine — the TCP/stdio loops below and the
/// golden tests drive it identically.
pub struct WireSession<'a> {
    serve: &'a mut dyn Serve,
    /// Events observed while streaming some other ticket; replayed when
    /// their ticket is streamed (dropped when the session ends).
    buffered: VecDeque<TokenEvent>,
}

/// Consecutive event-less pumps before the session starts sleeping between
/// pumps (covers the threaded server's non-blocking pump); engines in
/// prefill emit nothing for a few pumps and must not pay the sleep.
const IDLE_PUMPS_BEFORE_SLEEP: usize = 64;
/// Hard cap on sleepy pumps per stream verb (~30 s at 1 ms) — a stream on a
/// ticket that never progresses ends with `done:false` instead of hanging
/// the connection forever.
const MAX_SLEEPY_PUMPS: usize = 30_000;

impl<'a> WireSession<'a> {
    pub fn new(serve: &'a mut dyn Serve) -> Self {
        WireSession {
            serve,
            buffered: VecDeque::new(),
        }
    }

    /// Handle one request line; returns the reply lines and whether the
    /// server should shut down.
    pub fn handle_line(&mut self, line: &str) -> (Vec<String>, bool) {
        if line.trim().is_empty() {
            return (Vec::new(), false);
        }
        let req = match parse_request(line) {
            Ok(r) => r,
            Err(e) => return (vec![err_line(&e)], false),
        };
        match req {
            WireRequest::Submit(spec) => {
                let targets = spec.slo.targets();
                // Durable replay detection (PR 10): a key the journal has
                // already seen means `submit` will return the existing
                // ticket — flag it on the ack so clients can tell a replay
                // from a fresh admission.
                let replayed = spec
                    .idem_key
                    .and_then(|k| self.serve.journal().and_then(|j| j.lookup(k)))
                    .is_some();
                match self.serve.submit(spec) {
                    Ok(t) => {
                        let mut ack = Json::obj()
                            .set("ok", true)
                            .set("verb", "submit")
                            .set("ticket", t.id)
                            .set(
                                "class",
                                match t.class {
                                    TaskClass::Online => "online",
                                    TaskClass::Offline => "offline",
                                },
                            )
                            .set("submitted_at", t.submitted_at);
                        // SLO-guard admission verdict (PR 9): typed
                        // backpressure on the ack. Non-accept verdicts add
                        // the controller's retry hint; the ticket is
                        // already terminal (`cancelled` with reason
                        // `"shed"` on its stream).
                        let verdict = self.serve.last_verdict();
                        ack = ack.set("verdict", verdict.as_str());
                        if let Some(after) = verdict.retry_after() {
                            ack = ack.set("retry_after", after);
                        }
                        // Echo accepted per-ticket targets back (they are
                        // carried, not yet enforced — see SloClass docs).
                        if let Some(slo) = targets {
                            ack = ack.set("ttft", slo.ttft).set("tpot", slo.tpot);
                        }
                        if replayed {
                            ack = ack.set("replayed", true);
                        }
                        (vec![ack.to_string()], false)
                    }
                    Err(e) => (vec![err_line(&format!("submit: {e:#}"))], false),
                }
            }
            WireRequest::Cancel { ticket } => {
                let cancelled = self.serve.cancel(ticket);
                (
                    vec![Json::obj()
                        .set("ok", true)
                        .set("verb", "cancel")
                        .set("ticket", ticket)
                        .set("cancelled", cancelled)
                        .to_string()],
                    false,
                )
            }
            WireRequest::Stream { ticket, from_seq } => (self.stream(ticket, from_seq), false),
            WireRequest::Ack { ticket } => {
                let acked = self.serve.ack(ticket);
                (
                    vec![Json::obj()
                        .set("ok", true)
                        .set("verb", "ack")
                        .set("ticket", ticket)
                        .set("acked", acked)
                        .to_string()],
                    false,
                )
            }
            WireRequest::Metrics => (
                vec![Json::obj()
                    .set("ok", true)
                    .set("verb", "metrics")
                    .set("metrics", self.serve.snapshot().to_json())
                    .to_string()],
                false,
            ),
            WireRequest::Obs => (
                vec![Json::obj()
                    .set("ok", true)
                    .set("verb", "obs")
                    .set("obs", self.serve.obs())
                    .to_string()],
                false,
            ),
            WireRequest::Shutdown => (
                vec![Json::obj()
                    .set("ok", true)
                    .set("verb", "shutdown")
                    .to_string()],
                true,
            ),
        }
    }

    /// Is `t` a live durable ticket (its events are owned by the armed
    /// journal, not this session's buffer)?
    fn is_durable(&self, t: TicketId) -> bool {
        self.serve.journal().is_some_and(|j| j.is_durable(t))
    }

    /// Stream events. With a ticket: pump until that ticket's terminal
    /// event (events for other tickets are buffered for their own stream
    /// verbs); durable tickets are served from the journal with sequence
    /// numbers instead. Without a ticket: drain the whole deployment,
    /// emitting everything.
    fn stream(&mut self, ticket: Option<TicketId>, from_seq: Option<u64>) -> Vec<String> {
        if let Some(t) = ticket {
            if self.is_durable(t) {
                return self.stream_durable(t, from_seq.unwrap_or(0));
            }
            if from_seq.is_some() {
                return vec![err_line(
                    "stream: \"from_seq\" requires a durable ticket \
                     (journal disarmed, or the ticket was submitted without \
                     a key / already released)",
                )];
            }
        }
        let mut lines = Vec::new();
        let mut emitted = 0usize;
        let mut done = false;
        match ticket {
            Some(t) => {
                // Replay buffered events for this ticket first.
                let mut rest = VecDeque::with_capacity(self.buffered.len());
                for ev in self.buffered.drain(..) {
                    if ev.ticket() == t {
                        done |= ev.is_terminal();
                        lines.push(encode_event(&ev).to_string());
                        emitted += 1;
                    } else {
                        rest.push_back(ev);
                    }
                }
                self.buffered = rest;
                let mut idle = 0usize;
                let mut sleepy = 0usize;
                while !done {
                    let mut sink: Vec<TokenEvent> = Vec::new();
                    let progressed = match self.serve.pump(&mut sink) {
                        Ok(p) => p,
                        Err(e) => {
                            lines.push(err_line(&format!("pump: {e:#}")));
                            break;
                        }
                    };
                    let got = !sink.is_empty();
                    for ev in sink {
                        // Durable tickets' events live in the journal (they
                        // replay with their seq on that ticket's stream);
                        // buffering a second copy here would leak.
                        let durable = self.is_durable(ev.ticket());
                        if ev.ticket() == t {
                            done |= ev.is_terminal();
                            lines.push(encode_event(&ev).to_string());
                            emitted += 1;
                        } else if !durable {
                            self.buffered.push_back(ev);
                        }
                    }
                    if !progressed && !got {
                        break; // nothing left anywhere; ticket is stuck/gone
                    }
                    if got {
                        idle = 0;
                    } else {
                        idle += 1;
                        if idle >= IDLE_PUMPS_BEFORE_SLEEP {
                            sleepy += 1;
                            if sleepy > MAX_SLEEPY_PUMPS {
                                break;
                            }
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                    }
                }
            }
            None => {
                for ev in self.buffered.drain(..) {
                    lines.push(encode_event(&ev).to_string());
                    emitted += 1;
                }
                let mut sink: Vec<TokenEvent> = Vec::new();
                match self.serve.drain(&mut sink) {
                    Ok(()) => done = true,
                    Err(e) => lines.push(err_line(&format!("drain: {e:#}"))),
                }
                for ev in sink {
                    lines.push(encode_event(&ev).to_string());
                    emitted += 1;
                }
            }
        }
        lines.push(
            Json::obj()
                .set("ok", true)
                .set("verb", "stream")
                .set("done", done)
                .set("events", emitted)
                .to_string(),
        );
        lines
    }

    /// Stream a durable ticket from its journal ring (PR 10): every event
    /// line carries `"seq"`, delivery starts at `from_seq`, and the final
    /// summary advertises `"next_seq"` so a client that loses this
    /// connection can resume exactly where it stopped. The entry is left
    /// in place (terminal retention) until the client acks or TTL fires.
    fn stream_durable(&mut self, t: TicketId, from_seq: u64) -> Vec<String> {
        let mut lines = Vec::new();
        let mut next = from_seq;
        let mut emitted = 0usize;
        let mut done = false;
        let mut gap = false;
        if from_seq > 0 {
            if let Some(j) = self.serve.journal_mut() {
                j.note_resume();
            }
        }
        let mut pulled: Vec<(u64, TokenEvent)> = Vec::new();
        let mut idle = 0usize;
        let mut sleepy = 0usize;
        loop {
            pulled.clear();
            let res = self
                .serve
                .journal()
                .and_then(|j| j.replay(t, next, &mut pulled));
            let Some((g, terminal)) = res else {
                // Entry vanished mid-stream (acked elsewhere or TTL'd).
                break;
            };
            gap |= g;
            let got = !pulled.is_empty();
            for (seq, ev) in &pulled {
                lines.push(encode_event(ev).set("seq", *seq).to_string());
                next = seq + 1;
                emitted += 1;
            }
            if terminal {
                done = true;
                break;
            }
            // Not terminal yet: advance the deployment and pull again.
            let mut sink: Vec<TokenEvent> = Vec::new();
            let progressed = match self.serve.pump(&mut sink) {
                Ok(p) => p,
                Err(e) => {
                    lines.push(err_line(&format!("pump: {e:#}")));
                    break;
                }
            };
            let pumped = !sink.is_empty();
            for ev in sink {
                // The journal owns durable events; buffer only the rest
                // for their own (plain) stream verbs.
                let durable = self.is_durable(ev.ticket());
                if !durable {
                    self.buffered.push_back(ev);
                }
            }
            if !progressed && !pumped && !got {
                break; // nothing left anywhere; ticket is stuck/gone
            }
            if got || pumped {
                idle = 0;
            } else {
                idle += 1;
                if idle >= IDLE_PUMPS_BEFORE_SLEEP {
                    sleepy += 1;
                    if sleepy > MAX_SLEEPY_PUMPS {
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
        }
        let mut tail = Json::obj()
            .set("ok", true)
            .set("verb", "stream")
            .set("done", done)
            .set("events", emitted)
            .set("next_seq", next);
        if gap {
            tail = tail.set("gap", true);
        }
        lines.push(tail.to_string());
        lines
    }
}

// ---- transports ----------------------------------------------------------

/// Result of reading one frame from a connection.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete line, within the size cap (trailing `\r`/`\n` stripped).
    Line(String),
    /// The line exceeded `max` bytes; the payload was discarded, not
    /// buffered. Carries the total line length consumed.
    TooLarge(usize),
    /// The transport failed mid-line: `buffered` bytes of a partial frame
    /// had been accepted when the I/O error hit. Surfaced as a typed frame
    /// result — instead of silently dropping the partial bytes inside a
    /// raw `Err` — so the connection loop can account the loss before
    /// closing. A failure *between* frames (empty buffer) still returns
    /// `Err`: nothing was lost.
    Interrupted { buffered: usize, error: String },
    /// Clean end of stream.
    Eof,
}

/// Read one newline-delimited frame, never buffering more than `max`
/// bytes: once a line overflows the cap the remainder is consumed and
/// counted but dropped, so a hostile or buggy client cannot balloon
/// server memory with a single unbounded line.
pub fn read_frame<R: BufRead>(reader: &mut R, max: usize) -> std::io::Result<FrameRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut dropped = 0usize;
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) => c,
            Err(e) => {
                if buf.is_empty() && dropped == 0 {
                    return Err(e); // between frames: nothing was lost
                }
                return Ok(FrameRead::Interrupted {
                    buffered: buf.len() + dropped,
                    error: e.to_string(),
                });
            }
        };
        if chunk.is_empty() {
            // EOF: a non-empty trailing line (no newline) still counts.
            return Ok(if dropped > 0 {
                FrameRead::TooLarge(buf.len() + dropped)
            } else if buf.is_empty() {
                FrameRead::Eof
            } else {
                FrameRead::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if dropped == 0 && buf.len() + i <= max {
                    buf.extend_from_slice(&chunk[..i]);
                } else {
                    dropped += i;
                }
                reader.consume(i + 1);
                return Ok(if dropped > 0 {
                    FrameRead::TooLarge(buf.len() + dropped)
                } else {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    FrameRead::Line(String::from_utf8_lossy(&buf).into_owned())
                });
            }
            None => {
                let n = chunk.len();
                if dropped == 0 && buf.len() + n <= max {
                    buf.extend_from_slice(chunk);
                } else {
                    dropped += n;
                }
                reader.consume(n);
            }
        }
    }
}

/// Serve the protocol over TCP, one connection at a time (the coordinator
/// is single-threaded by design; a fleet front door is still one process).
/// Returns after a `shutdown` verb.
///
/// Per-connection failures — an unclonable socket, an oversized frame, an
/// I/O error mid-stream — close that connection only; the listener keeps
/// accepting. `conn_drop` is the chaos hook ([`FaultPlan::conn_drop`]):
/// when set, each connection is severed after that many frames, exercising
/// client reconnect paths deterministically.
///
/// [`FaultPlan::conn_drop`]: crate::faults::FaultPlan::conn_drop
pub fn serve_tcp_with<A: ToSocketAddrs>(
    addr: A,
    serve: &mut dyn Serve,
    conn_drop: Option<u64>,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("echo serve: listening on {}", listener.local_addr()?);
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                log::warn!("accept failed: {e}");
                continue;
            }
        };
        let mut reader = match stream.try_clone() {
            Ok(s) => BufReader::new(s),
            Err(e) => {
                log::warn!("connection unusable (clone failed): {e}");
                continue;
            }
        };
        let mut writer = BufWriter::new(stream);
        let mut session = WireSession::new(&mut *serve);
        let mut frames = 0u64;
        loop {
            let line = match read_frame(&mut reader, MAX_FRAME_BYTES) {
                Ok(FrameRead::Line(l)) => l,
                Ok(FrameRead::Eof) => break,
                Ok(FrameRead::TooLarge(len)) => {
                    let e = ServeError::FrameTooLarge {
                        len,
                        max: MAX_FRAME_BYTES,
                    };
                    let _ = writeln!(writer, "{}", err_line(&e.to_string()));
                    let _ = writer.flush();
                    break;
                }
                Ok(FrameRead::Interrupted { buffered, error }) => {
                    // A frame died mid-line (PR 10 satellite): surface the
                    // typed loss on the connection before closing — the
                    // peer may already be gone, so the reply is best
                    // effort, but the account is logged either way.
                    let e = ServeError::FrameInterrupted { buffered };
                    log::warn!("{e} ({error})");
                    let _ = writeln!(writer, "{}", err_line(&e.to_string()));
                    let _ = writer.flush();
                    break;
                }
                Err(e) => {
                    log::warn!("connection read failed: {e}");
                    break;
                }
            };
            frames += 1;
            if let Some(cap) = conn_drop {
                if frames > cap {
                    log::warn!("chaos: dropping connection after {cap} frames");
                    break;
                }
            }
            let (replies, shutdown) = session.handle_line(&line);
            let mut io_dead = false;
            for r in &replies {
                if writeln!(writer, "{r}").is_err() {
                    io_dead = true;
                    break;
                }
            }
            if writer.flush().is_err() || io_dead {
                break;
            }
            if shutdown {
                return Ok(());
            }
        }
    }
    Ok(())
}

/// [`serve_tcp_with`] without fault injection.
pub fn serve_tcp<A: ToSocketAddrs>(addr: A, serve: &mut dyn Serve) -> anyhow::Result<()> {
    serve_tcp_with(addr, serve, None)
}

/// Serve the protocol on stdin/stdout (scripting and tests without
/// sockets). Returns at EOF or after a `shutdown` verb.
pub fn serve_stdio(serve: &mut dyn Serve) -> anyhow::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let mut session = WireSession::new(serve);
    for line in stdin.lock().lines() {
        let line = line?;
        let (replies, shutdown) = session.handle_line(&line);
        for r in replies {
            writeln!(out, "{r}")?;
        }
        out.flush()?;
        if shutdown {
            break;
        }
    }
    Ok(())
}

//! The system-wide serving API (this repo's single front door).
//!
//! Echo's value proposition is *one* system co-serving latency-bound online
//! and throughput-bound offline work — but historically this reproduction
//! grew three unrelated submission surfaces (direct `Engine::submit_*`, the
//! mpsc `ServerHandle`, and `ClusterSim::run`'s batch replay). The [`Serve`]
//! trait unifies them:
//!
//!   * [`Serve::submit`] takes a [`SubmitSpec`] — prompt + typed
//!     [`SloClass`] (online TTFT/TPOT targets vs. offline best-effort) —
//!     and returns a client-held [`Ticket`];
//!   * [`Serve::pump`] advances the deployment by one unit of progress
//!     (engine step, cluster sync quantum, server event drain) and delivers
//!     [`TokenEvent`]s through an [`EventSink`], so per-token streaming and
//!     metrics share one path;
//!   * [`Serve::cancel`] withdraws a ticket: its KV interest, pool entry,
//!     and interned content keys are released (HyGen/ConServe-style cheap
//!     harvest of abandoned work);
//!   * [`Serve::snapshot`] returns a deployment-shape-independent
//!     [`MetricsView`].
//!
//! Three deployments implement it: [`engine::EngineServe`] (an `Engine`
//! driven inline on its virtual clock), `server::ServerHandle` (the
//! threaded wall-clock coordinator), and [`cluster::ClusterServe`] (router
//! dispatch + work-stealing over a replica fleet). [`wire`] exposes any of
//! them over a line-delimited-JSON protocol (`echo serve`).

pub mod cluster;
pub mod engine;
pub mod journal;
pub mod wire;

pub use cluster::ClusterServe;
pub use engine::EngineServe;
pub use journal::{JournalConfig, JournalStats, SessionJournal};

use std::collections::BTreeMap;

use crate::core::{Request, RequestId, RequestStore, Slo, TaskClass, Token};
use crate::faults::CancelReason;
use crate::utils::json::Json;

/// Client-visible handle id. For the bare-engine deployment this equals the
/// underlying `RequestId`; fleets assign their own (requests move between
/// replica stores, tickets do not).
pub type TicketId = u64;

/// Typed service class, replacing the scattered `TaskClass` + implicit
/// config-SLO coupling at submission sites.
#[derive(Clone, Copy, Debug)]
pub enum SloClass {
    /// Latency-sensitive: optional per-request TTFT/TPOT targets; `None`
    /// inherits the deployment-wide SLO. Scheduling currently enforces the
    /// deployment-wide SLO only — the per-ticket targets are carried for
    /// clients (the wire submit ack echoes them back) and for future
    /// per-ticket enforcement; no deployment applies them yet (see
    /// DESIGN.md "Serving API").
    Online(Option<Slo>),
    /// Throughput-oriented, best-effort, preemptible.
    Offline,
}

impl SloClass {
    pub fn task_class(self) -> TaskClass {
        match self {
            SloClass::Online(_) => TaskClass::Online,
            SloClass::Offline => TaskClass::Offline,
        }
    }

    /// The per-ticket SLO targets, if any.
    pub fn targets(self) -> Option<Slo> {
        match self {
            SloClass::Online(slo) => slo,
            SloClass::Offline => None,
        }
    }
}

/// Everything a deployment needs to admit one request.
#[derive(Clone, Debug)]
pub struct SubmitSpec {
    pub prompt: crate::core::PromptSpec,
    pub max_new_tokens: usize,
    pub slo: SloClass,
    /// Arrival on the deployment clock; `None` = "now" (the deployment's
    /// current virtual or wall clock).
    pub arrival: Option<f64>,
    /// Client-supplied idempotency key (PR 10 durable tickets). On a
    /// journal-armed deployment, a resubmit carrying a previously seen key
    /// returns the existing ticket instead of double-executing, and the
    /// ticket's events are retained for `stream {from_seq}` resume. `None`
    /// (the default) opts out of durability entirely.
    pub idem_key: Option<u64>,
}

impl SubmitSpec {
    pub fn online(prompt: crate::core::PromptSpec, max_new_tokens: usize) -> Self {
        SubmitSpec {
            prompt,
            max_new_tokens,
            slo: SloClass::Online(None),
            arrival: None,
            idem_key: None,
        }
    }

    pub fn offline(prompt: crate::core::PromptSpec, max_new_tokens: usize) -> Self {
        SubmitSpec {
            prompt,
            max_new_tokens,
            slo: SloClass::Offline,
            arrival: None,
            idem_key: None,
        }
    }

    /// Pin the arrival time (trace replay).
    pub fn at(mut self, arrival: f64) -> Self {
        self.arrival = Some(arrival);
        self
    }

    /// Attach per-ticket TTFT/TPOT targets (online only; no-op otherwise).
    pub fn with_targets(mut self, slo: Slo) -> Self {
        if let SloClass::Online(_) = self.slo {
            self.slo = SloClass::Online(Some(slo));
        }
        self
    }

    /// Attach an idempotency key, making the ticket durable on
    /// journal-armed deployments (replay-safe submit + resumable stream).
    pub fn with_key(mut self, key: u64) -> Self {
        self.idem_key = Some(key);
        self
    }
}

/// The client-held handle a submission returns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ticket {
    pub id: TicketId,
    pub class: TaskClass,
    /// Deployment-clock time the submission was accepted.
    pub submitted_at: f64,
}

/// Front-door admission verdict for a submission (PR 9 backpressure).
/// `submit` always returns a `Ticket` — a non-`Accept` verdict means the
/// ticket was created already terminal (an immediate
/// `Cancelled(CancelReason::Shed)` event follows on the next pump) and the
/// client should resubmit no sooner than the `retry_after` hint (deployment
/// seconds). Today only offline submits to a brownout-laddered cluster get
/// non-`Accept` verdicts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdmissionVerdict {
    /// Admitted normally.
    Accept,
    /// Rejected under brownout (ShedNewOffline rung): transient — retry
    /// after the hint.
    Retry { after: f64 },
    /// Rejected under Emergency: the fleet is actively preempting offline
    /// work; back off at least the hint, expect further rejections.
    Shed { after: f64 },
}

impl AdmissionVerdict {
    pub fn as_str(self) -> &'static str {
        match self {
            AdmissionVerdict::Accept => "accept",
            AdmissionVerdict::Retry { .. } => "retry",
            AdmissionVerdict::Shed { .. } => "shed",
        }
    }

    pub fn is_accept(self) -> bool {
        matches!(self, AdmissionVerdict::Accept)
    }

    /// The backoff hint, if any.
    pub fn retry_after(self) -> Option<f64> {
        match self {
            AdmissionVerdict::Accept => None,
            AdmissionVerdict::Retry { after } | AdmissionVerdict::Shed { after } => Some(after),
        }
    }
}

/// One step of a ticket's observable lifecycle, delivered through
/// [`EventSink`]s. Timestamps are deployment-clock seconds. `Preempted` is
/// informational: the ticket stays live and re-admits later (recompute
/// mode), so a same-engine stream sees `…Token, Preempted, Token…` with no
/// token loss. A cross-replica migration (cluster work-steal) regenerates
/// the output from scratch on the thief, so the fleet deployment emits
/// `Preempted` and *restarts* the stream from token 0 instead.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// Prefill completed; the first output token landed.
    FirstToken {
        ticket: TicketId,
        at: f64,
        token: Option<Token>,
    },
    /// A decode-step token (index counts from 0 = the first token).
    Token {
        ticket: TicketId,
        at: f64,
        token: Option<Token>,
        index: usize,
    },
    /// Recompute-mode preemption observed; the ticket will re-admit.
    Preempted { ticket: TicketId, at: f64 },
    /// Terminal: all tokens generated.
    Finished {
        ticket: TicketId,
        at: f64,
        tokens: Vec<Token>,
        ttft: Option<f64>,
        mean_tpot: Option<f64>,
    },
    /// Terminal: withdrawn before completion. `reason` distinguishes a
    /// client withdrawal from system-initiated termination (unschedulable,
    /// overload shed, stall, replica failure) — see
    /// [`crate::faults::CancelReason`].
    Cancelled {
        ticket: TicketId,
        at: f64,
        reason: CancelReason,
    },
}

impl TokenEvent {
    pub fn ticket(&self) -> TicketId {
        match *self {
            TokenEvent::FirstToken { ticket, .. }
            | TokenEvent::Token { ticket, .. }
            | TokenEvent::Preempted { ticket, .. }
            | TokenEvent::Finished { ticket, .. }
            | TokenEvent::Cancelled { ticket, .. } => ticket,
        }
    }

    pub fn at(&self) -> f64 {
        match *self {
            TokenEvent::FirstToken { at, .. }
            | TokenEvent::Token { at, .. }
            | TokenEvent::Preempted { at, .. }
            | TokenEvent::Finished { at, .. }
            | TokenEvent::Cancelled { at, .. } => at,
        }
    }

    /// Terminal events end a ticket's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(self, TokenEvent::Finished { .. } | TokenEvent::Cancelled { .. })
    }

    /// Short event-kind tag (wire protocol / logs).
    pub fn kind(&self) -> &'static str {
        match self {
            TokenEvent::FirstToken { .. } => "first_token",
            TokenEvent::Token { .. } => "token",
            TokenEvent::Preempted { .. } => "preempted",
            TokenEvent::Finished { .. } => "finished",
            TokenEvent::Cancelled { .. } => "cancelled",
        }
    }
}

/// Where deployments deliver [`TokenEvent`]s. One path serves both
/// streaming clients and metrics collectors.
pub trait EventSink {
    fn on_event(&mut self, ev: &TokenEvent);

    /// Event-discarding sinks return false so deployments can skip
    /// materializing per-token events entirely on batch paths (the cursor
    /// bookkeeping still advances; only the event construction is saved).
    fn wants_events(&self) -> bool {
        true
    }
}

/// Collect every event (tests, batch drivers).
impl EventSink for Vec<TokenEvent> {
    fn on_event(&mut self, ev: &TokenEvent) {
        self.push(ev.clone());
    }
}

/// Discard events (metrics-only callers).
pub struct NullSink;

impl EventSink for NullSink {
    fn on_event(&mut self, _ev: &TokenEvent) {}

    fn wants_events(&self) -> bool {
        false
    }
}

/// Adapt a closure into a sink.
pub struct FnSink<F: FnMut(&TokenEvent)>(pub F);

impl<F: FnMut(&TokenEvent)> EventSink for FnSink<F> {
    fn on_event(&mut self, ev: &TokenEvent) {
        (self.0)(ev)
    }
}

/// Deployment-shape-independent load/outcome snapshot.
#[derive(Clone, Debug)]
pub struct MetricsView {
    /// Deployment kind tag ("engine", "server", "cluster").
    pub deployment: &'static str,
    /// Deployment clock (virtual seconds; wall seconds for the server).
    pub clock: f64,
    /// Online requests accepted but not yet running.
    pub queued_online: usize,
    /// Offline requests pooled (per-engine pools + any fleet backlog).
    pub pooled_offline: usize,
    /// Requests currently in the running batch.
    pub running: usize,
    pub online_completed: usize,
    pub offline_completed: usize,
    pub cancelled: usize,
    pub preemptions: usize,
    pub busy_time: f64,
    pub online_throughput: f64,
    pub offline_throughput: f64,
    pub hit_ratio: f64,
    /// Live serving engines behind this front door.
    pub replicas: usize,
    /// Streaming latency percentiles + estimator audit (fleet-merged for
    /// the cluster deployment: histograms merge, so these are true fleet
    /// percentiles, not averages of per-replica percentiles).
    pub latency: crate::metrics::LatencyView,
    /// Durable-session journal counters (PR 10); all-zero when the
    /// deployment's journal is disarmed.
    pub journal: JournalStats,
}

impl Default for MetricsView {
    fn default() -> Self {
        MetricsView {
            deployment: "idle",
            clock: 0.0,
            queued_online: 0,
            pooled_offline: 0,
            running: 0,
            online_completed: 0,
            offline_completed: 0,
            cancelled: 0,
            preemptions: 0,
            busy_time: 0.0,
            online_throughput: 0.0,
            offline_throughput: 0.0,
            hit_ratio: 0.0,
            replicas: 0,
            latency: crate::metrics::LatencyView::default(),
            journal: JournalStats::default(),
        }
    }
}

impl MetricsView {
    /// Snapshot of a single engine — shared by the inline (`EngineServe`)
    /// and threaded (`server`) deployments, which differ only in the tag.
    pub fn of_engine<B: crate::engine::ExecutionBackend>(
        e: &crate::engine::Engine<B>,
        deployment: &'static str,
    ) -> MetricsView {
        let running = e
            .live_requests()
            .filter(|r| r.state == crate::core::ReqState::Running)
            .count();
        let m = &e.metrics;
        MetricsView {
            deployment,
            clock: e.clock,
            queued_online: e.backlog_online(),
            pooled_offline: e.pool.len(),
            running,
            online_completed: m.online_completed,
            offline_completed: m.offline_completed,
            cancelled: m.cancelled_online + m.cancelled_offline,
            preemptions: m.preemptions,
            busy_time: m.busy_time,
            online_throughput: m.online_throughput(),
            offline_throughput: m.offline_throughput(),
            hit_ratio: e.kv.stats.hit_ratio(),
            replicas: 1,
            latency: m.latency_view(),
            journal: JournalStats::default(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("deployment", self.deployment)
            .set("clock", self.clock)
            .set("queued_online", self.queued_online)
            .set("pooled_offline", self.pooled_offline)
            .set("running", self.running)
            .set("online_completed", self.online_completed)
            .set("offline_completed", self.offline_completed)
            .set("cancelled", self.cancelled)
            .set("preemptions", self.preemptions)
            .set("busy_time", self.busy_time)
            .set("online_throughput_tok_s", self.online_throughput)
            .set("offline_throughput_tok_s", self.offline_throughput)
            .set("hit_ratio", self.hit_ratio)
            .set("replicas", self.replicas)
            .set("latency", self.latency.to_json())
            .set("journal", self.journal.to_json())
    }
}

/// The one serving API. Object-safe: call sites hold `&mut dyn Serve`, so
/// the same driver script runs against a bare engine, the threaded server,
/// or a fleet.
pub trait Serve {
    /// Accept a request; returns the client-held ticket.
    fn submit(&mut self, spec: SubmitSpec) -> anyhow::Result<Ticket>;

    /// The admission verdict the most recent `submit` was given (PR 9
    /// backpressure). Deployments without a feedback controller always
    /// report `Accept`; `ClusterServe` overrides this to surface the SLO
    /// guard's `Retry`/`Shed` decisions so the wire layer can put the
    /// verdict (and its `retry_after` hint) on the submit ack.
    fn last_verdict(&self) -> AdmissionVerdict {
        AdmissionVerdict::Accept
    }

    /// Withdraw a ticket. Terminal: releases the request's KV interest,
    /// pool/queue entry, and interned content keys; a `Cancelled` event is
    /// delivered on the next pump. Returns false if the ticket is unknown
    /// or already terminal (for the threaded server: false if the server is
    /// gone — the cancel itself is asynchronous).
    fn cancel(&mut self, ticket: TicketId) -> bool;

    /// One unit of progress (engine iteration / cluster sync quantum /
    /// server event drain); delivers pending events. Returns false when no
    /// work remains to drive.
    fn pump(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<bool>;

    /// Run until all submitted work completes (or is cancelled).
    fn drain(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<()>;

    /// Run until the deployment clock reaches `deadline` (virtual seconds;
    /// wall seconds since start for the threaded server).
    fn run_until(&mut self, deadline: f64, sink: &mut dyn EventSink) -> anyhow::Result<()>;

    /// Deployment-shape-independent load/outcome snapshot.
    fn snapshot(&self) -> MetricsView;

    /// Arm the durable-session journal (PR 10). Returns false for
    /// deployments without journal support (the threaded server — its
    /// event fan-out crosses threads, so durability is only offered on the
    /// virtual-clock deployments for now).
    fn arm_journal(&mut self, cfg: JournalConfig) -> bool {
        let _ = cfg;
        false
    }

    /// The armed journal, if any.
    fn journal(&self) -> Option<&SessionJournal> {
        None
    }

    /// Mutable access to the armed journal (wire resume bookkeeping).
    fn journal_mut(&mut self) -> Option<&mut SessionJournal> {
        None
    }

    /// Acknowledge a durable ticket: its journal entry (replay buffer +
    /// idempotency-key binding) is released. Returns false when the ticket
    /// is unknown to the journal or the journal is disarmed.
    fn ack(&mut self, ticket: TicketId) -> bool {
        let _ = ticket;
        false
    }

    /// Observability report: latency/estimator histogram summaries plus
    /// whatever trace data the deployment holds. The default builds it from
    /// [`Serve::snapshot`] (no trace section); deployments that own trace
    /// rings override it to include per-replica ring stats and top
    /// recompute-cost requests (see [`crate::obs::summary`]).
    fn obs(&self) -> Json {
        crate::obs::summary_from_view(&self.snapshot())
    }
}

// ---- shared event-extraction machinery -----------------------------------

/// Per-ticket progress cursor: how much of a request's observable lifecycle
/// has been delivered as events. Works on *observed state* (the request's
/// recorded token times / preemption count), so deployments that advance
/// many iterations per pump still emit every token with its true timestamp.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Cursor {
    sent_tokens: usize,
    sent_preemptions: usize,
    terminal: bool,
}

impl Cursor {
    /// Emit everything newly observable on `r` since the last drain.
    /// `now` stamps events with no recorded time (preemption observations).
    /// Returns true when a terminal event was emitted.
    pub(crate) fn drain(
        &mut self,
        ticket: TicketId,
        r: &Request,
        now: f64,
        out: &mut Vec<TokenEvent>,
    ) -> bool {
        if self.terminal {
            return true;
        }
        while self.sent_preemptions < r.preemptions {
            self.sent_preemptions += 1;
            out.push(TokenEvent::Preempted { ticket, at: now });
        }
        while self.sent_tokens < r.token_times.len() {
            let i = self.sent_tokens;
            let at = r.token_times[i];
            let token = r.out_tokens.get(i).copied();
            out.push(if i == 0 {
                TokenEvent::FirstToken { ticket, at, token }
            } else {
                TokenEvent::Token {
                    ticket,
                    at,
                    token,
                    index: i,
                }
            });
            self.sent_tokens += 1;
        }
        if r.is_finished() {
            self.terminal = true;
            out.push(TokenEvent::Finished {
                ticket,
                at: r.finished_at.unwrap_or(now),
                tokens: r.out_tokens.clone(),
                ttft: r.ttft(),
                mean_tpot: r.mean_tpot(),
            });
        }
        self.terminal
    }

    /// Advance the cursor past everything currently observable without
    /// materializing events (event-discarding sinks); returns true when
    /// the request is terminal.
    pub(crate) fn fast_forward(&mut self, r: &Request) -> bool {
        self.sent_preemptions = r.preemptions;
        self.sent_tokens = r.token_times.len();
        self.terminal = self.terminal || r.is_finished();
        self.terminal
    }
}

/// Drain events for every tracked ticket of a single-store deployment
/// (ticket id == request id); terminal cursors are dropped.
pub(crate) fn collect_store_events(
    store: &RequestStore,
    cursors: &mut BTreeMap<RequestId, Cursor>,
    now: f64,
    out: &mut Vec<TokenEvent>,
) {
    let mut done: Vec<RequestId> = Vec::new();
    for (&id, cur) in cursors.iter_mut() {
        let Some(r) = store.try_get(id) else { continue };
        if cur.drain(id, r, now, out) {
            done.push(id);
        }
    }
    for id in done {
        cursors.remove(&id);
    }
}

/// `collect_store_events` for event-discarding sinks: advance and prune
/// cursors without building a single event.
pub(crate) fn skip_store_events(store: &RequestStore, cursors: &mut BTreeMap<RequestId, Cursor>) {
    let mut done: Vec<RequestId> = Vec::new();
    for (&id, cur) in cursors.iter_mut() {
        let Some(r) = store.try_get(id) else { continue };
        if cur.fast_forward(r) {
            done.push(id);
        }
    }
    for id in done {
        cursors.remove(&id);
    }
}

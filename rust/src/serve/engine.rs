//! [`Serve`] over a bare [`Engine`]: the inline, virtual-clock deployment
//! every sim driver (CLI `simulate`, figures, deployer sim, benches) runs
//! through. One `pump` = one engine iteration; events are derived from the
//! requests' recorded token times, so `run_until`/`drain` (which advance
//! many iterations at once) still deliver every token with its true
//! virtual-time stamp.

use std::collections::BTreeMap;

use crate::core::{Request, RequestId, TaskClass};
use crate::engine::{Engine, ExecutionBackend};

use super::{
    collect_store_events, Cursor, EventSink, JournalConfig, MetricsView, Serve, SessionJournal,
    SubmitSpec, Ticket, TicketId, TokenEvent,
};

pub struct EngineServe<B: ExecutionBackend> {
    pub engine: Engine<B>,
    cursors: BTreeMap<RequestId, Cursor>,
    /// Cancellation events queued for the next pump (cancel has no sink).
    pending: Vec<TokenEvent>,
    /// Durable-session journal (PR 10); `None` = disarmed (zero cost).
    journal: Option<SessionJournal>,
}

impl<B: ExecutionBackend> EngineServe<B> {
    pub fn new(engine: Engine<B>) -> Self {
        EngineServe {
            engine,
            cursors: BTreeMap::new(),
            pending: Vec::new(),
            journal: None,
        }
    }

    /// Consume the front door and recover the engine (final reporting).
    pub fn into_engine(self) -> Engine<B> {
        self.engine
    }

    fn flush(&mut self, sink: &mut dyn EventSink) {
        // Live durable tickets force event materialization even on the
        // batch path: their replay buffers must see every event.
        let journal_live = self.journal.as_ref().is_some_and(|j| !j.is_empty());
        if !sink.wants_events() && !journal_live {
            // Batch path (NullSink): advance/prune the cursors without
            // materializing one event per generated token.
            self.pending.clear();
            super::skip_store_events(&self.engine.store, &mut self.cursors);
            if let Some(j) = self.journal.as_mut() {
                j.expire(self.engine.clock);
            }
            return;
        }
        let mut evs = std::mem::take(&mut self.pending);
        collect_store_events(&self.engine.store, &mut self.cursors, self.engine.clock, &mut evs);
        if let Some(j) = self.journal.as_mut() {
            if journal_live {
                for ev in &evs {
                    j.append(ev, self.engine.clock);
                }
            }
            j.expire(self.engine.clock);
        }
        if sink.wants_events() {
            for ev in &evs {
                sink.on_event(ev);
            }
        }
    }
}

impl<B: ExecutionBackend> Serve for EngineServe<B> {
    fn submit(&mut self, spec: SubmitSpec) -> anyhow::Result<Ticket> {
        // Idempotent replay: a previously seen key returns its ticket
        // instead of admitting a second copy of the request.
        if let (Some(key), Some(j)) = (spec.idem_key, self.journal.as_mut()) {
            if let Some(t) = j.lookup(key) {
                j.stats.replayed_submits += 1;
                return Ok(t);
            }
        }
        let id = self.engine.store.fresh_id();
        let class = spec.slo.task_class();
        let arrival = spec.arrival.unwrap_or(self.engine.clock);
        let req = Request::new(id, class, arrival, spec.prompt, spec.max_new_tokens);
        match class {
            TaskClass::Online => self.engine.submit_online(req),
            TaskClass::Offline => self.engine.submit_offline(req),
        }
        self.cursors.insert(id, Cursor::default());
        let ticket = Ticket {
            id,
            class,
            submitted_at: arrival,
        };
        if let (Some(key), Some(j)) = (spec.idem_key, self.journal.as_mut()) {
            j.register(ticket, key);
        }
        Ok(ticket)
    }

    fn cancel(&mut self, ticket: TicketId) -> bool {
        if !self.engine.cancel(ticket) {
            return false;
        }
        self.cursors.remove(&ticket);
        self.pending.push(TokenEvent::Cancelled {
            ticket,
            at: self.engine.clock,
            reason: crate::faults::CancelReason::Client,
        });
        true
    }

    fn pump(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<bool> {
        let progressed = self.engine.step()?;
        self.flush(sink);
        Ok(progressed)
    }

    fn drain(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        let result = self.engine.run();
        self.flush(sink);
        result
    }

    fn run_until(&mut self, deadline: f64, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        let result = self.engine.run_until(deadline);
        self.flush(sink);
        result
    }

    fn snapshot(&self) -> MetricsView {
        let mut view = MetricsView::of_engine(&self.engine, "engine");
        if let Some(j) = self.journal.as_ref() {
            view.journal = j.stats.clone();
        }
        view
    }

    fn arm_journal(&mut self, cfg: JournalConfig) -> bool {
        if self.journal.is_none() {
            self.journal = Some(SessionJournal::new(cfg));
        }
        true
    }

    fn journal(&self) -> Option<&SessionJournal> {
        self.journal.as_ref()
    }

    fn journal_mut(&mut self) -> Option<&mut SessionJournal> {
        self.journal.as_mut()
    }

    fn ack(&mut self, ticket: TicketId) -> bool {
        self.journal.as_mut().is_some_and(|j| j.ack(ticket))
    }

    fn obs(&self) -> crate::utils::json::Json {
        match self.engine.trace() {
            Some(ring) => crate::obs::summary(&self.engine.metrics, &[(0, ring)]),
            None => crate::obs::summary(&self.engine.metrics, &[]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::core::PromptSpec;
    use crate::engine::sim::SimBackend;
    use crate::estimator::TimeModel;

    fn front() -> EngineServe<SimBackend> {
        let cfg = SystemConfig::a100_llama8b();
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), 1, 0.0);
        EngineServe::new(Engine::new(cfg, backend))
    }

    #[test]
    fn streams_tokens_then_finishes() {
        let mut s = front();
        let t = s.submit(SubmitSpec::online(PromptSpec::sim(200, None), 4).at(0.0)).unwrap();
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        let mine: Vec<&TokenEvent> = evs.iter().filter(|e| e.ticket() == t.id).collect();
        assert!(matches!(mine.first(), Some(TokenEvent::FirstToken { .. })));
        assert!(matches!(mine.last(), Some(TokenEvent::Finished { .. })));
        // first + 3 decode tokens + finished
        assert_eq!(mine.len(), 5);
        // Event times are the engine's recorded token times, ascending.
        assert!(mine.windows(2).all(|w| w[0].at() <= w[1].at()));
        assert_eq!(s.snapshot().online_completed, 1);
    }

    #[test]
    fn durable_submit_is_replay_safe_and_resumable() {
        use crate::serve::NullSink;
        let mut s = front();
        assert!(s.arm_journal(crate::serve::JournalConfig::default()));
        let spec = SubmitSpec::online(PromptSpec::sim(200, None), 4).at(0.0);
        let t = s.submit(spec.clone().with_key(42)).unwrap();
        let dup = s.submit(spec.with_key(42)).unwrap();
        assert_eq!(t.id, dup.id, "resubmit with the same key must not double-execute");
        // Drain through a NullSink: the journal must still capture the
        // durable ticket's full stream.
        s.drain(&mut NullSink).unwrap();
        let mut out = Vec::new();
        let (gap, terminal) = s.journal().unwrap().replay(t.id, 0, &mut out).unwrap();
        assert!(!gap && terminal, "full stream retained through terminal");
        let seqs: Vec<u64> = out.iter().map(|(q, _)| *q).collect();
        assert_eq!(seqs, (0..out.len() as u64).collect::<Vec<u64>>(), "contiguous seqs");
        assert!(matches!(out.last(), Some((_, TokenEvent::Finished { .. }))));
        assert_eq!(s.snapshot().journal.replayed_submits, 1);
        assert_eq!(s.snapshot().online_completed, 1, "executed exactly once");
        assert!(s.ack(t.id), "ack releases the entry");
        assert!(s.journal().unwrap().is_empty());
    }

    #[test]
    fn cancel_before_run_emits_cancelled_only() {
        let mut s = front();
        let t = s.submit(SubmitSpec::offline(PromptSpec::sim(500, None), 64)).unwrap();
        assert!(s.cancel(t.id));
        assert!(!s.cancel(t.id), "second cancel is a no-op");
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        assert_eq!(evs.len(), 1);
        assert!(matches!(evs[0], TokenEvent::Cancelled { .. }));
        assert_eq!(s.snapshot().cancelled, 1);
        assert_eq!(s.snapshot().offline_completed, 0);
    }
}

//! [`Serve`] over a bare [`Engine`]: the inline, virtual-clock deployment
//! every sim driver (CLI `simulate`, figures, deployer sim, benches) runs
//! through. One `pump` = one engine iteration; events are derived from the
//! requests' recorded token times, so `run_until`/`drain` (which advance
//! many iterations at once) still deliver every token with its true
//! virtual-time stamp.

use std::collections::BTreeMap;

use crate::core::{Request, RequestId, TaskClass};
use crate::engine::{Engine, ExecutionBackend};

use super::{
    collect_store_events, Cursor, EventSink, MetricsView, Serve, SubmitSpec, Ticket, TicketId,
    TokenEvent,
};

pub struct EngineServe<B: ExecutionBackend> {
    pub engine: Engine<B>,
    cursors: BTreeMap<RequestId, Cursor>,
    /// Cancellation events queued for the next pump (cancel has no sink).
    pending: Vec<TokenEvent>,
}

impl<B: ExecutionBackend> EngineServe<B> {
    pub fn new(engine: Engine<B>) -> Self {
        EngineServe {
            engine,
            cursors: BTreeMap::new(),
            pending: Vec::new(),
        }
    }

    /// Consume the front door and recover the engine (final reporting).
    pub fn into_engine(self) -> Engine<B> {
        self.engine
    }

    fn flush(&mut self, sink: &mut dyn EventSink) {
        if !sink.wants_events() {
            // Batch path (NullSink): advance/prune the cursors without
            // materializing one event per generated token.
            self.pending.clear();
            super::skip_store_events(&self.engine.store, &mut self.cursors);
            return;
        }
        let mut evs = std::mem::take(&mut self.pending);
        collect_store_events(&self.engine.store, &mut self.cursors, self.engine.clock, &mut evs);
        for ev in &evs {
            sink.on_event(ev);
        }
    }
}

impl<B: ExecutionBackend> Serve for EngineServe<B> {
    fn submit(&mut self, spec: SubmitSpec) -> anyhow::Result<Ticket> {
        let id = self.engine.store.fresh_id();
        let class = spec.slo.task_class();
        let arrival = spec.arrival.unwrap_or(self.engine.clock);
        let req = Request::new(id, class, arrival, spec.prompt, spec.max_new_tokens);
        match class {
            TaskClass::Online => self.engine.submit_online(req),
            TaskClass::Offline => self.engine.submit_offline(req),
        }
        self.cursors.insert(id, Cursor::default());
        Ok(Ticket {
            id,
            class,
            submitted_at: arrival,
        })
    }

    fn cancel(&mut self, ticket: TicketId) -> bool {
        if !self.engine.cancel(ticket) {
            return false;
        }
        self.cursors.remove(&ticket);
        self.pending.push(TokenEvent::Cancelled {
            ticket,
            at: self.engine.clock,
            reason: crate::faults::CancelReason::Client,
        });
        true
    }

    fn pump(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<bool> {
        let progressed = self.engine.step()?;
        self.flush(sink);
        Ok(progressed)
    }

    fn drain(&mut self, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        let result = self.engine.run();
        self.flush(sink);
        result
    }

    fn run_until(&mut self, deadline: f64, sink: &mut dyn EventSink) -> anyhow::Result<()> {
        let result = self.engine.run_until(deadline);
        self.flush(sink);
        result
    }

    fn snapshot(&self) -> MetricsView {
        MetricsView::of_engine(&self.engine, "engine")
    }

    fn obs(&self) -> crate::utils::json::Json {
        match self.engine.trace() {
            Some(ring) => crate::obs::summary(&self.engine.metrics, &[(0, ring)]),
            None => crate::obs::summary(&self.engine.metrics, &[]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::core::PromptSpec;
    use crate::engine::sim::SimBackend;
    use crate::estimator::TimeModel;

    fn front() -> EngineServe<SimBackend> {
        let cfg = SystemConfig::a100_llama8b();
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), 1, 0.0);
        EngineServe::new(Engine::new(cfg, backend))
    }

    #[test]
    fn streams_tokens_then_finishes() {
        let mut s = front();
        let t = s.submit(SubmitSpec::online(PromptSpec::sim(200, None), 4).at(0.0)).unwrap();
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        let mine: Vec<&TokenEvent> = evs.iter().filter(|e| e.ticket() == t.id).collect();
        assert!(matches!(mine.first(), Some(TokenEvent::FirstToken { .. })));
        assert!(matches!(mine.last(), Some(TokenEvent::Finished { .. })));
        // first + 3 decode tokens + finished
        assert_eq!(mine.len(), 5);
        // Event times are the engine's recorded token times, ascending.
        assert!(mine.windows(2).all(|w| w[0].at() <= w[1].at()));
        assert_eq!(s.snapshot().online_completed, 1);
    }

    #[test]
    fn cancel_before_run_emits_cancelled_only() {
        let mut s = front();
        let t = s.submit(SubmitSpec::offline(PromptSpec::sim(500, None), 64)).unwrap();
        assert!(s.cancel(t.id));
        assert!(!s.cancel(t.id), "second cancel is a no-op");
        let mut evs: Vec<TokenEvent> = Vec::new();
        s.drain(&mut evs).unwrap();
        assert_eq!(evs.len(), 1);
        assert!(matches!(evs[0], TokenEvent::Cancelled { .. }));
        assert_eq!(s.snapshot().cancelled, 1);
        assert_eq!(s.snapshot().offline_completed, 0);
    }
}

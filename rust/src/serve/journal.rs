//! Durable-session journal (PR 10): replay-safe submits and resumable
//! streams on the deployment's virtual clock.
//!
//! A wire connection is the *weakest* link in the serving path — PR 7's
//! `ConnDrop` faults sever it mid-stream, and a naive client that retries
//! its submit double-executes the request. The journal closes both holes
//! without touching scheduling:
//!
//!   * **Idempotency keys**: a submit that carries a client-supplied key is
//!     *durable*. The key maps to the ticket it first produced; a resubmit
//!     with the same key returns that existing ticket instead of admitting
//!     a second copy (`stats.replayed_submits` counts the saves).
//!   * **Replay buffer**: every [`TokenEvent`] of a durable ticket is
//!     assigned a monotone per-ticket sequence number and retained in a
//!     bounded ring. A reconnecting client issues `stream {from_seq}` and
//!     receives exactly the events it has not seen — no loss (unless the
//!     ring overflowed, which is surfaced as a `gap`), no duplicates.
//!   * **Terminal retention**: entries survive their terminal event until
//!     the client acks the ticket or `terminal_ttl` virtual seconds pass,
//!     so a client that disconnects *after* the final token can still
//!     observe it. `drain` semantics are unchanged — retention is pure
//!     bookkeeping, the underlying request is gone.
//!
//! Everything here runs in the deployment's single-threaded pump path on
//! the virtual clock, so journal-armed runs stay bit-exact across
//! `--threads`. Tickets submitted *without* a key are untouched: the armed
//! journal costs them one `is_empty` check per pump.

use std::collections::VecDeque;

use crate::serve::{Ticket, TicketId, TokenEvent};
use crate::utils::hash::FxHashMap;
use crate::utils::json::Json;

/// Journal tuning. Defaults suit test-sized runs; production would size the
/// ring by client bandwidth-delay product.
#[derive(Clone, Copy, Debug)]
pub struct JournalConfig {
    /// Max buffered events per durable ticket; older events are evicted
    /// (a resume from before the ring start reports a gap).
    pub replay_cap: usize,
    /// Virtual seconds a terminal entry is retained awaiting its ack.
    pub terminal_ttl: f64,
}

impl Default for JournalConfig {
    fn default() -> Self {
        JournalConfig {
            replay_cap: 256,
            terminal_ttl: 60.0,
        }
    }
}

/// Journal outcome counters (surfaced through `MetricsView::journal`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JournalStats {
    /// Durable tickets registered (submits that carried a key).
    pub registered: u64,
    /// Resubmits deduplicated onto an existing ticket (double-executions
    /// prevented).
    pub replayed_submits: u64,
    /// `stream {from_seq}` resumes served from the replay buffer.
    pub resumed_streams: u64,
    /// Events appended to replay buffers.
    pub buffered_events: u64,
    /// Events evicted from full rings (visible to resumers as a gap).
    pub dropped_events: u64,
    /// Terminal entries reaped by TTL instead of an ack.
    pub expired_terminals: u64,
    /// Entries released by an explicit client ack.
    pub acked: u64,
}

impl JournalStats {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("registered", self.registered)
            .set("replayed_submits", self.replayed_submits)
            .set("resumed_streams", self.resumed_streams)
            .set("buffered_events", self.buffered_events)
            .set("dropped_events", self.dropped_events)
            .set("expired_terminals", self.expired_terminals)
            .set("acked", self.acked)
    }
}

/// Per-durable-ticket state: the bounded event ring and its sequencing.
#[derive(Clone, Debug)]
struct JournalEntry {
    ticket: Ticket,
    /// (seq, event) pairs; front is the oldest retained event.
    buf: VecDeque<(u64, TokenEvent)>,
    /// Next sequence number to assign (== 1 + last assigned).
    next_seq: u64,
    /// Virtual time the terminal event landed, if it has.
    terminal_at: Option<f64>,
}

impl JournalEntry {
    /// Sequence number of the oldest retained event (`next_seq` when the
    /// ring is empty — nothing retained, nothing lost iff `next_seq == 0`).
    fn first_seq(&self) -> u64 {
        self.buf.front().map_or(self.next_seq, |(s, _)| *s)
    }
}

/// The session journal: idempotency-key dedup plus per-ticket replay rings.
/// Owned by a deployment (`EngineServe` / `ClusterServe`) and ticked from
/// its pump path.
#[derive(Clone, Debug, Default)]
pub struct SessionJournal {
    cfg: JournalConfig,
    /// Client idempotency key → the durable ticket it minted.
    keys: FxHashMap<u64, TicketId>,
    entries: FxHashMap<TicketId, JournalEntry>,
    /// Terminal-retention deadlines in arrival order (virtual time is
    /// monotone in the pump path, so this stays sorted).
    expiry: VecDeque<(f64, TicketId)>,
    pub stats: JournalStats,
}

impl SessionJournal {
    pub fn new(cfg: JournalConfig) -> Self {
        SessionJournal {
            cfg: JournalConfig {
                replay_cap: cfg.replay_cap.max(1),
                ..cfg
            },
            ..SessionJournal::default()
        }
    }

    /// True when no durable ticket is live — the armed-idle fast path: the
    /// pump skips event materialization exactly as if disarmed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The ticket a previously seen idempotency key minted, if any.
    pub fn lookup(&self, key: u64) -> Option<Ticket> {
        let id = *self.keys.get(&key)?;
        self.entries.get(&id).map(|e| e.ticket)
    }

    /// Register a freshly minted durable ticket under its idempotency key.
    /// First writer wins: a key already bound to a live entry is left
    /// untouched (the caller should have used [`SessionJournal::lookup`]).
    pub fn register(&mut self, ticket: Ticket, key: u64) {
        if let Some(existing) = self.keys.get(&key) {
            if self.entries.contains_key(existing) {
                return;
            }
        }
        self.keys.insert(key, ticket.id);
        self.entries.insert(
            ticket.id,
            JournalEntry {
                ticket,
                buf: VecDeque::new(),
                next_seq: 0,
                terminal_at: None,
            },
        );
        self.stats.registered += 1;
    }

    /// True when `ticket` is a live durable entry (its events are owned by
    /// the journal, not per-connection buffers).
    pub fn is_durable(&self, ticket: TicketId) -> bool {
        self.entries.contains_key(&ticket)
    }

    /// Append one event to its ticket's replay ring (no-op for non-durable
    /// tickets). Called from the deployment pump for every materialized
    /// event while the journal has live entries.
    // lint: hot-path
    pub fn append(&mut self, ev: &TokenEvent, now: f64) {
        let Some(entry) = self.entries.get_mut(&ev.ticket()) else {
            return;
        };
        let seq = entry.next_seq;
        entry.next_seq += 1;
        if entry.buf.len() >= self.cfg.replay_cap {
            entry.buf.pop_front();
            self.stats.dropped_events += 1;
        }
        // lint: allow-alloc(durable tickets buffer owned events; ring bounded by replay_cap)
        entry.buf.push_back((seq, ev.clone()));
        self.stats.buffered_events += 1;
        if ev.is_terminal() {
            entry.terminal_at = Some(now);
            self.expiry.push_back((now + self.cfg.terminal_ttl, ev.ticket()));
        }
    }

    /// Copy the retained events at or after `from_seq` into `out`. Returns
    /// `Some((gap, terminal_seen))` for durable tickets (`gap` = events
    /// before `from_seq`'s successor were already evicted), `None` for
    /// unknown tickets.
    pub fn replay(
        &self,
        ticket: TicketId,
        from_seq: u64,
        out: &mut Vec<(u64, TokenEvent)>,
    ) -> Option<(bool, bool)> {
        let entry = self.entries.get(&ticket)?;
        let gap = from_seq < entry.first_seq() && entry.first_seq() > 0;
        let mut terminal = false;
        for (seq, ev) in &entry.buf {
            if *seq < from_seq {
                continue;
            }
            terminal |= ev.is_terminal();
            out.push((*seq, ev.clone()));
        }
        Some((gap, terminal))
    }

    /// Count a successful `stream {from_seq}` resume.
    pub fn note_resume(&mut self) {
        self.stats.resumed_streams += 1;
    }

    /// Client acknowledges a ticket: its entry (and key binding) is
    /// released. Returns false for unknown/already-released tickets.
    pub fn ack(&mut self, ticket: TicketId) -> bool {
        let Some(entry) = self.entries.remove(&ticket) else {
            return false;
        };
        self.keys.retain(|_, id| *id != ticket);
        let _ = entry;
        self.stats.acked += 1;
        true
    }

    /// Reap terminal entries whose retention TTL has passed. Deadlines are
    /// pushed in monotone virtual time, so this is a front-of-queue check —
    /// O(1) when nothing is due.
    pub fn expire(&mut self, now: f64) {
        while let Some(&(deadline, ticket)) = self.expiry.front() {
            if deadline > now {
                break;
            }
            self.expiry.pop_front();
            // The entry may have been acked (or re-terminated never —
            // ticket ids are not reused) since the deadline was queued.
            let due = self
                .entries
                .get(&ticket)
                .and_then(|e| e.terminal_at)
                .is_some_and(|t| t + self.cfg.terminal_ttl <= now);
            if due {
                self.entries.remove(&ticket);
                self.keys.retain(|_, id| *id != ticket);
                self.stats.expired_terminals += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskClass;

    fn ticket(id: TicketId) -> Ticket {
        Ticket {
            id,
            class: TaskClass::Online,
            submitted_at: 0.0,
        }
    }

    fn tok(ticket: TicketId, at: f64, index: usize) -> TokenEvent {
        TokenEvent::Token {
            ticket,
            at,
            token: None,
            index,
        }
    }

    fn fin(t: TicketId, at: f64) -> TokenEvent {
        TokenEvent::Finished {
            ticket: t,
            at,
            tokens: Vec::new(),
            ttft: None,
            mean_tpot: None,
        }
    }

    #[test]
    fn idempotency_key_dedups_onto_first_ticket() {
        let mut j = SessionJournal::new(JournalConfig::default());
        assert!(j.lookup(7).is_none());
        j.register(ticket(1), 7);
        j.register(ticket(2), 9);
        assert_eq!(j.lookup(7).unwrap().id, 1);
        assert_eq!(j.lookup(9).unwrap().id, 2);
        // First writer wins: re-registering key 7 is a no-op.
        j.register(ticket(3), 7);
        assert_eq!(j.lookup(7).unwrap().id, 1);
        assert_eq!(j.stats.registered, 2);
    }

    #[test]
    fn replay_is_sequenced_and_bounded() {
        let mut j = SessionJournal::new(JournalConfig {
            replay_cap: 4,
            terminal_ttl: 10.0,
        });
        j.register(ticket(1), 1);
        for i in 0..6 {
            j.append(&tok(1, i as f64, i), i as f64);
        }
        // Non-durable ticket events are ignored.
        j.append(&tok(99, 0.0, 0), 0.0);
        let mut out = Vec::new();
        let (gap, term) = j.replay(1, 0, &mut out).unwrap();
        assert!(gap, "seqs 0..2 were evicted");
        assert!(!term);
        assert_eq!(out.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![2, 3, 4, 5]);
        out.clear();
        let (gap, _) = j.replay(1, 4, &mut out).unwrap();
        assert!(!gap);
        assert_eq!(out.len(), 2);
        assert_eq!(j.stats.dropped_events, 2);
        assert!(j.replay(99, 0, &mut out).is_none());
    }

    #[test]
    fn terminal_entries_survive_until_ack_or_ttl() {
        let mut j = SessionJournal::new(JournalConfig {
            replay_cap: 8,
            terminal_ttl: 5.0,
        });
        j.register(ticket(1), 1);
        j.register(ticket(2), 2);
        j.append(&fin(1, 1.0), 1.0);
        j.append(&fin(2, 2.0), 2.0);
        j.expire(3.0);
        assert!(j.is_durable(1) && j.is_durable(2), "TTL not reached yet");
        assert!(j.ack(1), "ack releases the entry");
        assert!(!j.ack(1), "double-ack is a no-op");
        j.expire(7.5);
        assert!(!j.is_durable(2), "TTL reaps the unacked terminal");
        assert!(j.lookup(2).is_none(), "key binding dies with the entry");
        assert_eq!(j.stats.acked, 1);
        assert_eq!(j.stats.expired_terminals, 1);
        assert!(j.is_empty());
    }
}

//! Observability: deterministic iteration-level tracing and summaries.
//!
//! The trace collector is a bounded ring of typed, virtual-clock-stamped
//! events owned by each engine (one per replica in a cluster). Tracing is
//! opt-in: a disabled engine carries `Option::None` and every hook is a
//! single branch — nothing allocates in the steady step loop, preserving
//! the `engine_step_allocs_steady == 0` invariant. Enabled, the ring is
//! pre-allocated up front and `push` never allocates either; once full it
//! overwrites the oldest event and counts the drop.
//!
//! Exporters turn collected rings into Chrome-trace/Perfetto JSON
//! ([`chrome_trace`]) or an aggregate report ([`summary`], rendered for the
//! terminal by [`render_summary`]). Event timestamps are the engine's
//! virtual clock, so traces are bit-identical across worker thread counts.

use std::collections::BTreeMap;

use crate::core::RequestId;
use crate::metrics::Metrics;
use crate::utils::json::Json;

/// Default ring capacity: 64Ki events (~3 MiB per replica). At one
/// iteration event plus a handful of lifecycle events per step this covers
/// tens of thousands of iterations before wrapping.
pub const DEFAULT_TRACE_EVENTS: usize = 1 << 16;

/// One virtual-clock-stamped trace event. All variants are `Copy` so the
/// ring can overwrite slots without touching the heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// Request entered the system (online queue or offline pool).
    Submit { t: f64, req: RequestId, online: bool },
    /// Scheduler admitted the request; `wait` is time since arrival.
    Admit {
        t: f64,
        req: RequestId,
        online: bool,
        wait: f64,
    },
    /// First output token emitted (prefill completed).
    FirstToken { t: f64, req: RequestId },
    /// Preempted and evicted; `cost_tokens` is the prefill length that must
    /// be recomputed (modulo prefix-cache hits) on re-admission.
    Preempt {
        t: f64,
        req: RequestId,
        cost_tokens: u32,
    },
    /// Request completed; `tokens` is the output length.
    Finish {
        t: f64,
        req: RequestId,
        online: bool,
        tokens: u32,
    },
    /// Withdrawn through the serving API before completion.
    Cancel { t: f64, req: RequestId },
    /// One executed engine iteration: batch composition, scheduler trial
    /// count, and predicted (`est`, 0 = estimator off) vs actual (`dur`)
    /// execution time.
    Iteration {
        start: f64,
        dur: f64,
        prefills: u32,
        decodes: u32,
        tokens: u32,
        trials: u32,
        est: f64,
    },
    /// KV-cache activity delta over one iteration (emitted only when some
    /// counter moved): prefix lookups/hits, evictions, superseded entries.
    Kv {
        t: f64,
        lookups: u32,
        hits: u32,
        evictions: u32,
        superseded: u32,
    },
    /// SLO-guard brownout ladder transition (PR 9): `from`/`to` are
    /// [`crate::slo::BrownoutLevel`] ranks. Emitted into every live
    /// replica's ring at the coordinator tick so Perfetto shows the
    /// brownout span on each replica track.
    Brownout { t: f64, from: u8, to: u8 },
    /// Gray-failure ladder transition (PR 10): `from`/`to` are
    /// [`crate::cluster::HealthState`] ranks (0 healthy, 1 probation,
    /// 2 quarantined). Emitted into the affected replica's own ring at the
    /// coordinator tick that moved it.
    Health { t: f64, replica: u32, from: u8, to: u8 },
}

impl TraceEvent {
    /// The event's (start) timestamp on the virtual clock.
    pub fn timestamp(&self) -> f64 {
        match *self {
            TraceEvent::Submit { t, .. }
            | TraceEvent::Admit { t, .. }
            | TraceEvent::FirstToken { t, .. }
            | TraceEvent::Preempt { t, .. }
            | TraceEvent::Finish { t, .. }
            | TraceEvent::Cancel { t, .. }
            | TraceEvent::Kv { t, .. }
            | TraceEvent::Brownout { t, .. }
            | TraceEvent::Health { t, .. } => t,
            TraceEvent::Iteration { start, .. } => start,
        }
    }
}

/// Fixed-capacity event ring. The buffer is allocated once at construction;
/// `push` is branch + store, overwriting the oldest event when full.
#[derive(Clone, Debug)]
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Oldest live slot once the ring has wrapped (0 before that).
    head: usize,
    dropped: u64,
}

impl TraceRing {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        TraceRing {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
        }
    }

    /// Append an event, overwriting the oldest when full. Never allocates:
    /// the backing buffer was sized at construction.
    // lint: hot-path
    pub fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.dropped += 1;
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Live events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf[self.head..].iter().chain(&self.buf[..self.head])
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total events ever pushed (live + dropped).
    pub fn total(&self) -> u64 {
        self.buf.len() as u64 + self.dropped
    }
}

fn micros(t: f64) -> f64 {
    t * 1e6
}

/// One Chrome-trace event object. Iterations become `ph:"X"` duration
/// events on the iteration track (tid 0); request lifecycle events become
/// instants on the request track (tid 1) with admit→finish also bracketed
/// as an async span (`ph:"b"/"e"`, id = request id) so Perfetto draws one
/// bar per in-flight request; KV deltas are instants on tid 2.
fn event_json(pid: usize, ev: &TraceEvent, out: &mut Vec<Json>) {
    let base = |name: &str, ph: &str, tid: usize, ts: f64| {
        Json::obj()
            .set("name", name)
            .set("ph", ph)
            .set("pid", pid)
            .set("tid", tid)
            .set("ts", micros(ts))
    };
    match *ev {
        TraceEvent::Submit { t, req, online } => {
            out.push(
                base("submit", "i", 1, t)
                    .set("s", "t")
                    .set("args", Json::obj().set("req", req).set("online", online)),
            );
        }
        TraceEvent::Admit { t, req, online, wait } => {
            out.push(
                base("request", "b", 1, t)
                    .set("cat", "request")
                    .set("id", req)
                    .set(
                        "args",
                        Json::obj()
                            .set("req", req)
                            .set("online", online)
                            .set("queue_wait_s", wait),
                    ),
            );
        }
        TraceEvent::FirstToken { t, req } => {
            out.push(
                base("first_token", "i", 1, t)
                    .set("s", "t")
                    .set("args", Json::obj().set("req", req)),
            );
        }
        TraceEvent::Preempt { t, req, cost_tokens } => {
            let args = Json::obj().set("req", req).set("cost_tokens", cost_tokens as u64);
            out.push(base("preempt", "i", 1, t).set("s", "t").set("args", args));
        }
        TraceEvent::Finish { t, req, online, tokens } => {
            out.push(
                base("request", "e", 1, t)
                    .set("cat", "request")
                    .set("id", req)
                    .set(
                        "args",
                        Json::obj()
                            .set("req", req)
                            .set("online", online)
                            .set("tokens", tokens as u64),
                    ),
            );
        }
        TraceEvent::Cancel { t, req } => {
            out.push(
                base("cancel", "i", 1, t)
                    .set("s", "t")
                    .set("args", Json::obj().set("req", req)),
            );
        }
        TraceEvent::Iteration { start, dur, prefills, decodes, tokens, trials, est } => {
            let args = Json::obj()
                .set("prefills", prefills as u64)
                .set("decodes", decodes as u64)
                .set("tokens", tokens as u64)
                .set("trials", trials as u64)
                .set("est_s", est)
                .set("actual_s", dur);
            out.push(base("iteration", "X", 0, start).set("dur", micros(dur)).set("args", args));
        }
        TraceEvent::Kv { t, lookups, hits, evictions, superseded } => {
            let args = Json::obj()
                .set("lookups", lookups as u64)
                .set("hits", hits as u64)
                .set("evictions", evictions as u64)
                .set("superseded", superseded as u64);
            out.push(base("kv", "i", 2, t).set("s", "t").set("args", args));
        }
        TraceEvent::Brownout { t, from, to } => {
            let level_name = |v: u8| match v {
                0 => "normal",
                1 => "pause_offline_admission",
                2 => "drain_offline_running",
                3 => "shed_new_offline",
                _ => "emergency",
            };
            let args = Json::obj()
                .set("from", level_name(from))
                .set("to", level_name(to))
                .set("from_level", from as u64)
                .set("to_level", to as u64);
            out.push(base("brownout", "i", 0, t).set("s", "p").set("args", args));
        }
        TraceEvent::Health { t, replica, from, to } => {
            let state_name = |v: u8| match v {
                0 => "healthy",
                1 => "probation",
                _ => "quarantined",
            };
            let args = Json::obj()
                .set("replica", replica as u64)
                .set("from", state_name(from))
                .set("to", state_name(to))
                .set("from_state", from as u64)
                .set("to_state", to as u64);
            out.push(base("health", "i", 0, t).set("s", "p").set("args", args));
        }
    }
}

/// Export rings as a Chrome-trace / Perfetto JSON object (`traceEvents`
/// array). One process per replica (pid = replica id) with named tracks:
/// tid 0 iterations, tid 1 request lifecycle, tid 2 KV cache. Pass tracks
/// in replica-id order for a deterministic file.
pub fn chrome_trace(tracks: &[(usize, &TraceRing)]) -> Json {
    let mut events = Vec::new();
    for &(pid, ring) in tracks {
        let meta = |name: &str, val: Json| {
            Json::obj()
                .set("name", name)
                .set("ph", "M")
                .set("pid", pid)
                .set("tid", 0)
                .set("args", val)
        };
        events.push(meta("process_name", Json::obj().set("name", format!("replica-{pid}"))));
        for (tid, label) in [(0, "iterations"), (1, "requests"), (2, "kv")] {
            events.push(
                Json::obj()
                    .set("name", "thread_name")
                    .set("ph", "M")
                    .set("pid", pid)
                    .set("tid", tid)
                    .set("args", Json::obj().set("name", label)),
            );
        }
        for ev in ring.events() {
            event_json(pid, ev, &mut events);
        }
    }
    Json::obj()
        .set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", "ms")
}

/// Highest-recompute-cost requests derived from `Preempt` events: each
/// preemption evicts the request's KV blocks, so its prefill (minus any
/// later prefix-cache hit) must be recomputed. Returns up to `k` entries
/// sorted by total cost descending, ties by request id.
pub fn top_recompute(tracks: &[(usize, &TraceRing)], k: usize) -> Vec<(RequestId, u64, usize)> {
    let mut per_req: BTreeMap<RequestId, (u64, usize)> = BTreeMap::new();
    for &(_, ring) in tracks {
        for ev in ring.events() {
            if let TraceEvent::Preempt { req, cost_tokens, .. } = *ev {
                let e = per_req.entry(req).or_insert((0, 0));
                e.0 += cost_tokens as u64;
                e.1 += 1;
            }
        }
    }
    let mut rows: Vec<(RequestId, u64, usize)> =
        per_req.into_iter().map(|(r, (c, n))| (r, c, n)).collect();
    rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    rows.truncate(k);
    rows
}

fn recompute_json(rows: &[(RequestId, u64, usize)]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|&(req, cost, n)| {
                Json::obj()
                    .set("req", req)
                    .set("cost_tokens", cost)
                    .set("preemptions", n)
            })
            .collect(),
    )
}

/// Aggregate observability report over a (possibly merged) metrics rollup
/// and the fleet's trace rings: latency/estimator histograms, counters, and
/// per-replica trace accounting with the top-K recompute offenders.
pub fn summary(m: &Metrics, tracks: &[(usize, &TraceRing)]) -> Json {
    let replicas: Vec<Json> = tracks
        .iter()
        .map(|&(id, ring)| {
            Json::obj()
                .set("replica", id)
                .set("events", ring.len())
                .set("dropped", ring.dropped())
        })
        .collect();
    Json::obj()
        .set("latency", m.latency_view().to_json())
        .set(
            "counters",
            Json::obj()
                .set("iterations", m.iterations)
                .set("preemptions", m.preemptions)
                .set("online_completed", m.online_completed)
                .set("offline_completed", m.offline_completed)
                .set("cancelled_online", m.cancelled_online)
                .set("cancelled_offline", m.cancelled_offline)
                .set("exec_faults", m.exec_faults)
                .set("exec_retries", m.exec_retries),
        )
        .set(
            "trace",
            Json::obj()
                .set("replicas", Json::Arr(replicas))
                .set("top_recompute", recompute_json(&top_recompute(tracks, 10))),
        )
}

/// The same report shape built from a [`crate::serve::MetricsView`]
/// snapshot — the default `Serve::obs` path for front ends that do not own
/// trace rings.
pub fn summary_from_view(v: &crate::serve::MetricsView) -> Json {
    Json::obj()
        .set("latency", v.latency.to_json())
        .set(
            "counters",
            Json::obj()
                .set("preemptions", v.preemptions)
                .set("online_completed", v.online_completed)
                .set("offline_completed", v.offline_completed)
                .set("cancelled", v.cancelled),
        )
        .set(
            "trace",
            Json::obj()
                .set("replicas", Json::Arr(Vec::new()))
                .set("top_recompute", Json::Arr(Vec::new())),
        )
}

fn fmt_ms(j: Option<&Json>) -> String {
    match j.and_then(Json::as_f64) {
        Some(x) => format!("{:.1}", x * 1e3),
        None => "-".into(),
    }
}

/// Render a [`summary`] JSON object as an aligned terminal table: one row
/// per histogram (count/mean/p50/p90/p99), the estimator bias, and the
/// top-K recompute list.
pub fn render_summary(j: &Json) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:>8} {:>9} {:>9} {:>9} {:>9}\n",
        "metric", "count", "mean_ms", "p50_ms", "p90_ms", "p99_ms"
    ));
    for (label, key) in [
        ("ttft", "latency.ttft"),
        ("tpot", "latency.tpot"),
        ("queue_wait", "latency.queue_wait"),
    ] {
        let count = j
            .at(&format!("{key}.count"))
            .and_then(Json::as_u64)
            .unwrap_or(0);
        s.push_str(&format!(
            "{:<12} {:>8} {:>9} {:>9} {:>9} {:>9}\n",
            label,
            count,
            fmt_ms(j.at(&format!("{key}.mean"))),
            fmt_ms(j.at(&format!("{key}.p50"))),
            fmt_ms(j.at(&format!("{key}.p90"))),
            fmt_ms(j.at(&format!("{key}.p99"))),
        ));
    }
    let est_n = j
        .at("latency.estimator.count")
        .and_then(Json::as_u64)
        .unwrap_or(0);
    if est_n > 0 {
        let pct = |p: &str| {
            j.at(&format!("latency.estimator.{p}"))
                .and_then(Json::as_f64)
                .map(|x| format!("{:.1}%", x * 100.0))
                .unwrap_or_else(|| "-".into())
        };
        s.push_str(&format!(
            "estimator    {est_n} audited iterations | abs rel err mean {} p50 {} p99 {} | bias {}\n",
            pct("mean"),
            pct("p50"),
            pct("p99"),
            pct("bias"),
        ));
    } else {
        s.push_str("estimator    no audited iterations\n");
    }
    if let Some(rows) = j.at("trace.top_recompute").and_then(Json::as_arr) {
        if !rows.is_empty() {
            s.push_str("top recompute cost (preempted requests):\n");
            for r in rows {
                s.push_str(&format!(
                    "  req {:>6}  {:>8} tokens  {:>3} preemptions\n",
                    r.at("req").and_then(Json::as_u64).unwrap_or(0),
                    r.at("cost_tokens").and_then(Json::as_u64).unwrap_or(0),
                    r.at("preemptions").and_then(Json::as_u64).unwrap_or(0),
                ));
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instant(t: f64, req: RequestId) -> TraceEvent {
        TraceEvent::FirstToken { t, req }
    }

    #[test]
    fn ring_keeps_most_recent_in_order() {
        let mut r = TraceRing::with_capacity(4);
        assert!(r.is_empty());
        for i in 0..6 {
            r.push(instant(i as f64, i));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 2);
        assert_eq!(r.total(), 6);
        let ids: Vec<RequestId> = r
            .events()
            .map(|e| match *e {
                TraceEvent::FirstToken { req, .. } => req,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![2, 3, 4, 5]);
    }

    #[test]
    fn push_never_reallocates() {
        let mut r = TraceRing::with_capacity(8);
        let cap_before = r.buf.capacity();
        for i in 0..100 {
            r.push(instant(0.0, i));
        }
        assert_eq!(r.buf.capacity(), cap_before);
    }

    #[test]
    fn chrome_trace_shape_and_microseconds() {
        let mut r = TraceRing::with_capacity(16);
        r.push(TraceEvent::Submit {
            t: 0.5,
            req: 7,
            online: true,
        });
        r.push(TraceEvent::Iteration {
            start: 1.0,
            dur: 0.25,
            prefills: 2,
            decodes: 3,
            tokens: 67,
            trials: 4,
            est: 0.24,
        });
        let j = chrome_trace(&[(3, &r)]);
        let evs = j.at("traceEvents").and_then(Json::as_arr).unwrap();
        // 1 process_name + 3 thread_name metadata + 2 events.
        assert_eq!(evs.len(), 6);
        assert_eq!(
            evs[0].at("args.name").and_then(Json::as_str),
            Some("replica-3")
        );
        let iter = evs
            .iter()
            .find(|e| e.at("ph").and_then(Json::as_str) == Some("X"))
            .unwrap();
        assert_eq!(iter.at("ts").and_then(Json::as_f64), Some(1e6));
        assert_eq!(iter.at("dur").and_then(Json::as_f64), Some(0.25 * 1e6));
        assert_eq!(iter.at("pid").and_then(Json::as_usize), Some(3));
        assert_eq!(iter.at("args.trials").and_then(Json::as_u64), Some(4));
        // Round-trips through the parser.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.at("traceEvents").and_then(Json::as_arr).unwrap().len(),
            6
        );
    }

    #[test]
    fn top_recompute_aggregates_and_ranks() {
        let mut a = TraceRing::with_capacity(16);
        let mut b = TraceRing::with_capacity(16);
        a.push(TraceEvent::Preempt {
            t: 1.0,
            req: 1,
            cost_tokens: 100,
        });
        a.push(TraceEvent::Preempt {
            t: 2.0,
            req: 2,
            cost_tokens: 300,
        });
        b.push(TraceEvent::Preempt {
            t: 3.0,
            req: 1,
            cost_tokens: 250,
        });
        let rows = top_recompute(&[(0, &a), (1, &b)], 10);
        assert_eq!(rows, vec![(1, 350, 2), (2, 300, 1)]);
        assert_eq!(top_recompute(&[(0, &a)], 1).len(), 1);
    }

    #[test]
    fn summary_renders_table() {
        let mut m = Metrics::default();
        m.record_completion(crate::core::TaskClass::Online, 10, 50, Some(0.2), Some(0.03));
        m.record_estimate(1.1, 1.0);
        let mut r = TraceRing::with_capacity(8);
        r.push(TraceEvent::Preempt {
            t: 1.0,
            req: 9,
            cost_tokens: 64,
        });
        let j = summary(&m, &[(0, &r)]);
        assert!(j.at("latency.ttft.p50").is_some());
        assert_eq!(
            j.at("trace.top_recompute").and_then(Json::as_arr).unwrap().len(),
            1
        );
        let text = render_summary(&j);
        assert!(text.contains("ttft"));
        assert!(text.contains("queue_wait"));
        assert!(text.contains("req      9"));
        assert!(text.contains("audited iterations"));
    }
}

//! Echo leader entrypoint. CLI surface is wired up in `echo::cli`.
fn main() {
    std::process::exit(echo::run_cli());
}

//! `echo` binary command surface.
//!
//! Subcommands:
//!   serve      — the serving front door: line-delimited-JSON wire protocol
//!                (submit/cancel/stream/metrics/obs) over the `Serve` trait,
//!                for one engine or a co-simulated fleet
//!   serve-demo — threaded server demo load on the real PJRT model
//!   simulate   — mixed online/offline run on the cost-model backend
//!   obs        — traced simulation + observability summary (histogram
//!                table, estimator-accuracy audit, top recompute costs)
//!   estimate   — deployer resource/throughput estimation (paper §5.4)
//!   calibrate  — fit Eq. 6-8 coefficients against the PJRT backend
//!   trace-gen  — generate a paper-shaped arrival trace to a JSON file
//!   figures    — regenerate a paper table/figure (same code as `cargo bench`)
//!   lint       — repo-invariant static analysis (determinism, hot-path
//!                allocations, unwrap hygiene, oracle/gate/doc coverage)
//!   smoke      — PJRT wiring check

use crate::cluster::{ClusterConfig, ScalePolicy};
use crate::config::{SchedulerKind, SystemConfig};
use crate::core::PromptSpec;
#[cfg(feature = "runtime")]
use crate::engine::pjrt::PjrtBackend;
use crate::engine::{sim::SimBackend, Engine};
use crate::estimator::TimeModel;
use crate::figures;
#[cfg(feature = "runtime")]
use crate::runtime::ModelRuntime;
use crate::serve::{wire, ClusterServe, EngineServe, NullSink, Serve, SubmitSpec};
use crate::sim::DeployerSim;
use crate::trace::{Trace, TraceConfig};
use crate::utils::cli::Cli;
use crate::utils::json::Json;
use crate::utils::rng::Rng;
use crate::workload::{synthesize, DatasetSpec};

const ABOUT: &str = "echo — co-scheduling of hybrid online-offline LLM serving tasks";

pub fn run_cli() -> i32 {
    let mut argv: Vec<String> = std::env::args().collect();
    let program = if argv.is_empty() { "echo".into() } else { argv.remove(0) };
    if argv.is_empty() {
        eprintln!(
            "{ABOUT}\n\nSubcommands: serve, serve-demo, simulate, cluster, obs, estimate, \
             calibrate, trace-gen, figures, lint, smoke\nRun `{program} <cmd> --help` for options."
        );
        return 2;
    }
    let cmd = argv.remove(0);
    let res = match cmd.as_str() {
        "serve" => serve(&program, argv),
        "serve-demo" => serve_demo(&program, argv),
        "simulate" => simulate(&program, argv),
        "cluster" => cluster(&program, argv),
        "obs" => obs_cmd(&program, argv),
        "estimate" => estimate(&program, argv),
        "calibrate" => calibrate(&program, argv),
        "trace-gen" => trace_gen(&program, argv),
        "figures" => figures_cmd(&program, argv),
        "lint" => lint_cmd(&program, argv),
        "smoke" => smoke(),
        other => {
            eprintln!("unknown subcommand {other:?}");
            return 2;
        }
    };
    match res {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("echo {cmd}: {e:#}");
            1
        }
    }
}

fn parse_or_usage(cli: &Cli, program: &str, argv: Vec<String>) -> Result<crate::utils::cli::Args, anyhow::Error> {
    cli.parse_from(program, argv).map_err(|usage| anyhow::anyhow!("{usage}"))
}

fn load_config(args: &crate::utils::cli::Args) -> anyhow::Result<SystemConfig> {
    let mut cfg = if !args.str("config").is_empty() {
        SystemConfig::load(&args.str("config"))?
    } else {
        SystemConfig::preset(&args.str("preset"))?
    };
    if !args.str("strategy").is_empty() {
        cfg.scheduler.kind = SchedulerKind::parse(&args.str("strategy"))?;
    }
    Ok(cfg)
}

/// The serving front door: any `Serve` deployment behind the wire protocol.
fn serve(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new(
        "serving front door: line-delimited JSON (submit/cancel/stream/\
         metrics/obs/shutdown verbs) over the Serve trait",
    )
    .opt("preset", "a100_llama8b", "config preset")
    .opt("config", "", "config JSON file (overrides preset)")
    .opt("strategy", "", "override scheduler strategy")
    .opt(
        "replicas",
        "1",
        "1 = threaded wall-clock server; >1 = co-simulated fleet (virtual time)",
    )
    .opt(
        "threads",
        "1",
        "fleet worker threads per sync quantum (>1 replicas only; 1 = serial)",
    )
    .opt("listen", "127.0.0.1:7878", "TCP bind address")
    .flag("stdio", "speak the protocol on stdin/stdout instead of TCP")
    .flag(
        "durable",
        "arm the durable-session journal: idempotency-keyed submits are \
         replay-safe and streams resume via {from_seq} after a disconnect \
         (fleet mode, --replicas > 1)",
    )
    .opt(
        "trace-out",
        "",
        "write a Chrome-trace/Perfetto JSON of the session when it ends",
    )
    .opt("seed", "42", "rng seed");
    let args = parse_or_usage(&cli, program, argv)?;
    let mut cfg = load_config(&args)?;
    let seed = args.u64("seed").map_err(anyhow::Error::msg)?;
    let replicas = args.usize("replicas").map_err(anyhow::Error::msg)?.max(1);
    let slo = cfg.slo;
    cfg.seed = seed;
    let listen = args.str("listen");
    let trace_out = args.str("trace-out");
    if replicas == 1 {
        if args.flag("durable") {
            // The threaded server fans events out on another thread; the
            // journal's exactly-once replay contract needs the virtual
            // clock pump. Refuse loudly rather than half-honor it.
            eprintln!(
                "echo serve: --durable needs the co-simulated fleet \
                 (--replicas > 1); ignoring"
            );
        }
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), seed, 0.0);
        let mut engine = Engine::new(cfg, backend);
        if !trace_out.is_empty() {
            engine.enable_trace(crate::obs::DEFAULT_TRACE_EVENTS);
        }
        let mut handle = crate::server::spawn(engine);
        if args.flag("stdio") {
            wire::serve_stdio(&mut handle)?;
        } else {
            wire::serve_tcp(listen.as_str(), &mut handle)?;
        }
        let engine = handle.shutdown();
        if let (false, Some(ring)) = (trace_out.is_empty(), engine.trace()) {
            std::fs::write(&trace_out, crate::obs::chrome_trace(&[(0, ring)]).to_string())?;
            eprintln!("echo serve: wrote {trace_out}");
        }
        println!("{}", engine.metrics.to_json(&slo).pretty());
    } else {
        let mut cc = ClusterConfig::new(cfg, replicas);
        cc.threads = args.usize("threads").map_err(anyhow::Error::msg)?.max(1);
        if !trace_out.is_empty() {
            cc.trace_events = crate::obs::DEFAULT_TRACE_EVENTS;
        }
        let mut front = ClusterServe::new(cc);
        if args.flag("durable") {
            front.arm_journal(crate::serve::JournalConfig::default());
        }
        if args.flag("stdio") {
            wire::serve_stdio(&mut front)?;
        } else {
            wire::serve_tcp(listen.as_str(), &mut front)?;
        }
        if !trace_out.is_empty() {
            std::fs::write(&trace_out, front.sim.chrome_trace().to_string())?;
            eprintln!("echo serve: wrote {trace_out}");
        }
        let horizon = front.clock().max(1e-9);
        println!("{}", front.sim.report(horizon).to_json().pretty());
    }
    Ok(())
}

#[cfg(not(feature = "runtime"))]
fn serve_demo(_program: &str, _argv: Vec<String>) -> anyhow::Result<()> {
    anyhow::bail!(
        "built without the `runtime` feature: the PJRT backend is unavailable \
         (add the external `xla` dependency and rebuild with `--features runtime`)"
    )
}

#[cfg(feature = "runtime")]
fn serve_demo(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    use crate::serve::TokenEvent;
    let cli = Cli::new("serve a demo load on the real EchoLM model via PJRT")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("strategy", "echo", "bs | bs+e | bs+e+s | echo")
        .opt("online", "12", "number of online demo requests")
        .opt("offline", "8", "number of offline demo requests")
        .opt("seed", "42", "rng seed");
    let args = parse_or_usage(&cli, program, argv)?;

    let rt = ModelRuntime::load(args.str("artifacts"))?;
    println!(
        "loaded {} (platform={}, buckets={:?}, {} params)",
        rt.manifest.kv_shape.len(),
        rt.platform(),
        rt.buckets(),
        rt.manifest.params.len()
    );
    let mut cfg = SystemConfig::cpu_echolm();
    cfg.scheduler.kind = SchedulerKind::parse(&args.str("strategy"))?;
    cfg.scheduler.max_batch = rt.manifest.max_batch;
    cfg.cache.capacity_tokens = rt.manifest.max_batch * rt.manifest.max_seq;
    let vocab = rt.manifest.vocab as u32;
    let engine = Engine::new(cfg, PjrtBackend::new(rt));
    let handle = crate::server::spawn(engine);

    let mut rng = Rng::new(args.u64("seed").map_err(anyhow::Error::msg)?);
    let n_off = args.usize("offline").map_err(anyhow::Error::msg)?;
    let n_on = args.usize("online").map_err(anyhow::Error::msg)?;
    let shared: Vec<u32> = (0..32).map(|_| rng.range_u64(1, (vocab - 1) as u64) as u32).collect();
    for _ in 0..n_off {
        let mut t = shared.clone();
        t.extend((0..16).map(|_| rng.range_u64(1, (vocab - 1) as u64) as u32));
        handle.submit_detached(SubmitSpec::offline(PromptSpec::real(t), 8))?;
    }
    let mut rxs = Vec::new();
    for _ in 0..n_on {
        let t: Vec<u32> = (0..40).map(|_| rng.range_u64(1, (vocab - 1) as u64) as u32).collect();
        rxs.push(handle.submit_streaming(SubmitSpec::online(PromptSpec::real(t), 8))?);
        std::thread::sleep(std::time::Duration::from_millis(30));
    }
    for (i, (_ticket, rx)) in rxs.into_iter().enumerate() {
        loop {
            let ev = rx.recv_timeout(std::time::Duration::from_secs(120))?;
            if let TokenEvent::Finished {
                tokens,
                ttft,
                mean_tpot,
                ..
            } = ev
            {
                println!(
                    "online #{i}: {} tokens, ttft={:.1}ms tpot={:.1}ms",
                    tokens.len(),
                    ttft.unwrap_or(0.0) * 1e3,
                    mean_tpot.unwrap_or(0.0) * 1e3
                );
                break;
            }
        }
    }
    let engine = handle.shutdown();
    println!("{}", engine.metrics.to_json(&engine.cfg.slo).pretty());
    Ok(())
}

fn simulate(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("mixed online/offline run on the cost-model backend")
        .opt("preset", "a100_llama8b", "config preset")
        .opt("config", "", "config JSON file (overrides preset)")
        .opt("strategy", "", "override scheduler strategy")
        .opt("horizon", "600", "sim horizon, seconds")
        .opt("rate", "12", "mean online arrival rate, req/s")
        .opt("offline-dataset", "loogle_qa_short", "sharegpt | loogle_qa_short | loogle_qa_long | toolbench | nextqa")
        .opt("offline-count", "0", "offline backlog size (0 = auto)")
        .opt("seed", "42", "rng seed")
        .opt(
            "trace-out",
            "",
            "write a Chrome-trace/Perfetto JSON of the run to this path",
        )
        .opt("out", "", "write metrics JSON to this path");
    let args = parse_or_usage(&cli, program, argv)?;
    let cfg = load_config(&args)?;
    let horizon = args.f64("horizon").map_err(anyhow::Error::msg)?;
    let rate = args.f64("rate").map_err(anyhow::Error::msg)?;
    let seed = args.u64("seed").map_err(anyhow::Error::msg)?;

    let spec = dataset_by_name(&args.str("offline-dataset"))?;
    let backend = SimBackend::new(TimeModel::new(cfg.time_model), seed, 0.02);
    let slo = cfg.slo;
    let kind = cfg.scheduler.kind;
    let mut front = EngineServe::new(Engine::new(cfg, backend));
    front.engine.set_sample_interval(horizon / 480.0);
    if !args.str("trace-out").is_empty() {
        front.engine.enable_trace(crate::obs::DEFAULT_TRACE_EVENTS);
    }
    let n_off = args.usize("offline-count").map_err(anyhow::Error::msg)?;
    submit_mixed_load(&mut front, horizon, rate, &spec, n_off, seed)?;
    front.run_until(horizon, &mut NullSink)?;
    let e = front.into_engine();
    if let Some(ring) = e.trace() {
        let path = args.str("trace-out");
        std::fs::write(&path, crate::obs::chrome_trace(&[(0, ring)]).to_string())?;
        println!("wrote {path}");
    }
    let j = e
        .metrics
        .to_json(&slo)
        .set("strategy", kind.name())
        .set("offline_dataset", spec.name)
        .set("hit_ratio", e.kv.stats.hit_ratio())
        .set("horizon", horizon);
    println!("{}", j.pretty());
    if !args.str("out").is_empty() {
        std::fs::write(args.str("out"), j.pretty())?;
    }
    Ok(())
}

/// Submit the standard mixed load through a serving front door: tidal
/// online arrivals plus a shuffled offline corpus whose submission order
/// interleaves prefix groups (see `figures::run_mixed`). Shared by
/// `simulate` and `obs`. `offline_count` 0 auto-sizes from the horizon.
fn submit_mixed_load(
    front: &mut EngineServe<SimBackend>,
    horizon: f64,
    rate: f64,
    spec: &DatasetSpec,
    offline_count: usize,
    seed: u64,
) -> anyhow::Result<()> {
    let trace = Trace::generate(&TraceConfig::compressed(horizon, rate, seed));
    let mut rng = Rng::new(seed);
    for &t in &trace.arrivals {
        let len = rng.range_usize(50, 600);
        let out = rng.range_usize(16, 256);
        front.submit(SubmitSpec::online(PromptSpec::sim(len, None), out).at(t))?;
    }
    let n_off = if offline_count == 0 {
        figures::backlog_size(spec, horizon)
    } else {
        offline_count
    };
    let mut scratch = crate::core::RequestStore::new();
    let mut batch = synthesize(
        spec,
        n_off,
        crate::core::TaskClass::Offline,
        0.0,
        &mut scratch,
        &mut rng,
    );
    rng.shuffle(&mut batch.ids);
    for &id in &batch.ids {
        let r = scratch.get(id);
        front.submit(SubmitSpec::offline(r.prompt.clone(), r.max_new_tokens))?;
    }
    Ok(())
}

/// Traced run + observability report: histogram table (TTFT/TPOT/queue
/// wait), estimator-accuracy audit, and the top recompute-cost requests.
fn obs_cmd(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new(
        "traced simulation + observability summary: latency/estimator \
         histogram table and top recompute-cost requests",
    )
    .opt("preset", "a100_llama8b", "config preset")
    .opt("config", "", "config JSON file (overrides preset)")
    .opt("strategy", "", "override scheduler strategy")
    .opt("horizon", "120", "sim horizon, seconds")
    .opt("rate", "12", "mean online arrival rate, req/s")
    .opt("offline-dataset", "loogle_qa_short", "sharegpt | loogle_qa_short | loogle_qa_long | toolbench | nextqa")
    .opt("offline-count", "0", "offline backlog size (0 = auto)")
    .opt("trace-events", "65536", "per-engine trace ring capacity (events)")
    .opt("seed", "42", "rng seed")
    .opt(
        "trace-out",
        "",
        "also write the Chrome-trace/Perfetto JSON to this path",
    )
    .opt("out", "", "write the summary JSON to this path");
    let args = parse_or_usage(&cli, program, argv)?;
    let cfg = load_config(&args)?;
    let horizon = args.f64("horizon").map_err(anyhow::Error::msg)?;
    let rate = args.f64("rate").map_err(anyhow::Error::msg)?;
    let seed = args.u64("seed").map_err(anyhow::Error::msg)?;
    let spec = dataset_by_name(&args.str("offline-dataset"))?;

    let backend = SimBackend::new(TimeModel::new(cfg.time_model), seed, 0.02);
    let mut front = EngineServe::new(Engine::new(cfg, backend));
    let events = args.usize("trace-events").map_err(anyhow::Error::msg)?.max(1);
    front.engine.enable_trace(events);
    let n_off = args.usize("offline-count").map_err(anyhow::Error::msg)?;
    submit_mixed_load(&mut front, horizon, rate, &spec, n_off, seed)?;
    front.run_until(horizon, &mut NullSink)?;
    let e = front.into_engine();
    // lint: allow-unwrap(enable_trace ran a few lines up; trace() is Some)
    let ring = e.trace().expect("tracing was enabled above");
    let summary = crate::obs::summary(&e.metrics, &[(0, ring)]);
    print!("{}", crate::obs::render_summary(&summary));
    if !args.str("trace-out").is_empty() {
        let path = args.str("trace-out");
        std::fs::write(&path, crate::obs::chrome_trace(&[(0, ring)]).to_string())?;
        println!("wrote {path}");
    }
    if !args.str("out").is_empty() {
        std::fs::write(args.str("out"), summary.pretty())?;
        println!("wrote {}", args.str("out"));
    }
    Ok(())
}

fn cluster(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new(
        "multi-replica co-serving: prefix-affinity router, offline \
         work-stealing, tidal autoscaling",
    )
    .opt("preset", "a100_llama8b", "per-replica config preset")
    .opt("config", "", "config JSON file (overrides preset)")
    .opt("strategy", "", "override scheduler strategy")
    .opt("replicas", "4", "initial replica count")
    .opt("horizon", "240", "sim horizon, seconds (the tide compresses onto it)")
    .opt("rate", "12", "mean online arrival rate across the cluster, req/s")
    .opt("offline-dataset", "loogle_qa_short", "sharegpt | loogle_qa_short | loogle_qa_long | toolbench | nextqa")
    .opt("offline-count", "0", "offline backlog size (0 = auto from horizon x replicas)")
    .opt("sync-dt", "0.25", "router/digest sync quantum, seconds")
    .opt(
        "threads",
        "1",
        "worker threads for the per-quantum replica advance (1 = serial; \
         the parallel path is bit-exact with serial)",
    )
    .flag("autoscale", "scale the fleet with the tide (deployer-estimator driven)")
    .opt("min-replicas", "1", "autoscale floor")
    .opt("max-replicas", "0", "autoscale ceiling (0 = 2x --replicas)")
    .flag(
        "slo-guard",
        "arm the measured-latency SLO guard (AIMD offline caps, admission \
         backpressure, brownout ladder)",
    )
    .opt(
        "guard-target",
        "0.9",
        "SLO-guard attainment floor that triggers escalation (with --slo-guard)",
    )
    .opt(
        "offline-cap",
        "0",
        "static offline tokens-per-quantum reservation per replica (0 = off; \
         composes with --slo-guard as a ceiling)",
    )
    .flag(
        "quarantine",
        "arm the gray-failure monitor: estimator-drift health ladder; sick \
         replicas are routed around, drained, and respawned under fresh ids",
    )
    .opt(
        "chaos-seed",
        "0",
        "inject a seeded fault plan (crashes/slowdowns/exec errors; 0 = off)",
    )
    .opt(
        "chaos-intensity",
        "1",
        "fault-plan density multiplier (with --chaos-seed; <1 thins, >1 stacks)",
    )
    .opt("seed", "42", "rng seed")
    .opt(
        "trace-out",
        "",
        "write a fleet Chrome-trace/Perfetto JSON (one track per replica)",
    )
    .opt("out", "", "write the cluster report JSON to this path");
    let args = parse_or_usage(&cli, program, argv)?;
    let mut base = load_config(&args)?;
    let horizon = args.f64("horizon").map_err(anyhow::Error::msg)?;
    let rate = args.f64("rate").map_err(anyhow::Error::msg)?;
    let seed = args.u64("seed").map_err(anyhow::Error::msg)?;
    let replicas = args.usize("replicas").map_err(anyhow::Error::msg)?.max(1);
    base.seed = seed;

    let mut cc = ClusterConfig::new(base, replicas);
    cc.sync_dt = args.f64("sync-dt").map_err(anyhow::Error::msg)?.max(1e-3);
    cc.threads = args.usize("threads").map_err(anyhow::Error::msg)?.max(1);
    let static_cap = args.usize("offline-cap").map_err(anyhow::Error::msg)?;
    if static_cap != 0 {
        cc.offline_cap = static_cap;
    }
    if args.flag("slo-guard") {
        let mut g = crate::slo::SloGuardConfig::default();
        g.target = args.f64("guard-target").map_err(anyhow::Error::msg)?.clamp(0.0, 1.0);
        g.recover = g.recover.max(g.target);
        cc.guard = Some(g);
    }
    if args.flag("quarantine") {
        cc.health = Some(crate::cluster::HealthConfig::default());
    }
    let chaos_seed = args.u64("chaos-seed").map_err(anyhow::Error::msg)?;
    if chaos_seed != 0 {
        let intensity = args.f64("chaos-intensity").map_err(anyhow::Error::msg)?;
        cc.faults = crate::workload::chaos_overlay(chaos_seed, horizon, replicas, intensity);
        println!(
            "chaos: seed {chaos_seed} x{intensity} -> {} fault event(s)",
            cc.faults.events.len()
        );
    }
    if !args.str("trace-out").is_empty() {
        cc.trace_events = crate::obs::DEFAULT_TRACE_EVENTS;
    }
    // Largest fleet the run can reach — backlog auto-sizing must cover it.
    let mut fleet_cap = replicas;
    if args.flag("autoscale") {
        let min = args.usize("min-replicas").map_err(anyhow::Error::msg)?.max(1);
        let mut max = args.usize("max-replicas").map_err(anyhow::Error::msg)?;
        if max == 0 {
            max = replicas * 2;
        }
        let max = max.max(min);
        cc.scale = Some(ScalePolicy::tidal(min, max));
        fleet_cap = max;
    }

    let spec = dataset_by_name(&args.str("offline-dataset"))?;
    let mut n_off = args.usize("offline-count").map_err(anyhow::Error::msg)?;
    if n_off == 0 {
        n_off = figures::backlog_size(&spec, horizon) * fleet_cap;
    }

    let trace = Trace::generate(&TraceConfig::compressed(horizon, rate, seed));
    // Session-prefix online mix (multi-turn/system-prompt reuse) so the
    // router's prefix affinity has real shared prefixes to exploit.
    let online = crate::cluster::online_jobs_from_trace(
        &trace,
        &crate::cluster::online_session_spec(),
        seed ^ 0x00ff,
    );
    println!(
        "cluster: {} replicas{} x {} advance thread(s) | {} online arrivals \
         over {horizon:.0}s (tidal, mean {rate}/s) | {n_off} offline jobs ({})",
        replicas,
        if cc.scale.is_some() { " (autoscaled)" } else { "" },
        cc.threads,
        online.len(),
        spec.name
    );

    // Everything goes through the one serving API: offline jobs and the
    // trace replay are ordinary submissions against the fleet front door.
    let mut front = ClusterServe::new(cc);
    front.submit_offline_jobs(crate::cluster::offline_jobs(&spec, n_off, seed ^ 0x0ff0))?;
    front.submit_online_jobs(&online)?;
    front.run_until(horizon, &mut NullSink)?;
    let report = front.sim.report(horizon);

    let rows: Vec<Vec<String>> = report
        .replicas
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.replica),
                format!("{:.0}", r.spawned_at),
                r.retired_at.map_or("-".into(), |t| format!("{t:.0}")),
                format!("{}", r.online_completed),
                format!("{:.1}%", r.ttft_attainment * 100.0),
                format!("{:.1}%", r.token_attainment * 100.0),
                format!("{}", r.offline_completed),
                format!("{}", r.offline_billed_tokens),
                format!("{:.1}%", r.hit_ratio * 100.0),
                format!("{}", r.preemptions),
            ]
        })
        .collect();
    println!(
        "{}",
        crate::utils::ascii::table(
            "Per-replica SLO attainment and offline service",
            &[
                "Replica", "spawn", "retire", "online", "TTFT att.", "token att.",
                "offline", "billed tok", "hit ratio", "preempt",
            ],
            &rows,
        )
    );
    println!(
        "aggregate: offline throughput {:.1} tok/s over the horizon \
         ({:.1} tok/s per busy-second)",
        report.offline_throughput,
        report.aggregate.offline_throughput()
    );
    println!(
        "online SLO attainment: ttft {:.3}, per-token {:.3} \
         ({} completions across the fleet)",
        report.online_attainment.0,
        report.online_attainment.1,
        report.aggregate.online_completed
    );
    println!(
        "cluster cache-hit rate: {:.1}% | router: {} dispatched, {} by \
         affinity ({} predicted hit-tokens), {} capacity vetoes, {} overflow",
        report.cluster_hit_ratio * 100.0,
        report.router.dispatched_online,
        report.router.affinity_routed,
        report.router.predicted_hit_tokens,
        report.router.capacity_vetoes,
        report.router.overflow_dispatches
    );
    println!(
        "fleet: peak {} replicas, mean {:.2}; backlog remaining {}",
        report.peak_replicas, report.mean_replicas, report.backlog_remaining
    );
    if report.faults.any() {
        println!(
            "faults: {} crash(es) recovered (mean time-to-recovery {:.2}s), \
             {} online re-dispatched, {} offline re-queued, {} tokens \
             recomputed; shed {} offline / {} online; {} stalled cancel(s)",
            report.faults.crashes,
            if report.faults.crashes == 0 {
                0.0
            } else {
                report.faults.recovery_time / report.faults.crashes as f64
            },
            report.faults.online_redispatched,
            report.faults.offline_requeued,
            report.faults.tokens_recomputed,
            report.faults.shed_offline,
            report.faults.shed_online,
            report.faults.stalled_cancels
        );
    }
    if args.flag("quarantine") {
        println!(
            "quarantine: {} probation(s), {} recovery(ies), {} quarantine(s), \
             {} respawn(s)",
            report.health.probations,
            report.health.recoveries,
            report.health.quarantines,
            report.health.respawns
        );
    }
    if args.flag("slo-guard") {
        println!(
            "slo-guard: {} transition(s) ({} up / {} down), {} paused \
             quantum(s), {} emergency preemption(s); backpressured {} retry / \
             {} shed; final attainment {:.3}, offline cap {}",
            report.guard.transitions,
            report.guard.escalations,
            report.guard.deescalations,
            report.guard.pause_ticks,
            report.guard.emergency_preempted,
            report.guard.retry_submits,
            report.guard.shed_submits,
            report.guard.last_attainment,
            if report.guard.cap == usize::MAX {
                "unbounded".to_string()
            } else {
                report.guard.cap.to_string()
            }
        );
    }
    if !args.str("trace-out").is_empty() {
        let path = args.str("trace-out");
        std::fs::write(&path, front.sim.chrome_trace().to_string())?;
        println!("wrote {path}");
    }
    if !args.str("out").is_empty() {
        std::fs::write(args.str("out"), report.to_json().pretty())?;
        println!("wrote {}", args.str("out"));
    }
    Ok(())
}

fn estimate(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("deployer resource & throughput estimation (paper §5.4)")
        .opt("preset", "a100_llama8b", "config preset")
        .opt("config", "", "config JSON file")
        .opt("strategy", "", "override scheduler strategy")
        .opt("horizon", "600", "trace horizon, seconds")
        .opt("rate", "12", "mean online arrival rate, req/s")
        .opt("offline-dataset", "loogle_qa_short", "offline dataset")
        .opt("offline-count", "200", "offline backlog size")
        .opt("seed", "42", "rng seed");
    let args = parse_or_usage(&cli, program, argv)?;
    let cfg = load_config(&args)?;
    let horizon = args.f64("horizon").map_err(anyhow::Error::msg)?;
    let rate = args.f64("rate").map_err(anyhow::Error::msg)?;
    let seed = args.u64("seed").map_err(anyhow::Error::msg)?;
    let spec = dataset_by_name(&args.str("offline-dataset"))?;

    let trace = Trace::generate(&TraceConfig::compressed(horizon, rate, seed));
    let sim = DeployerSim::new(cfg);
    // Peak window: around the tidal peak (13/24 of the compressed day).
    let peak_mid = 13.0 / 24.0 * horizon;
    let window = (peak_mid - horizon / 24.0, peak_mid + horizon / 24.0);
    let report = sim.report(
        &trace,
        window,
        &spec,
        args.usize("offline-count").map_err(anyhow::Error::msg)?,
        horizon,
    )?;
    println!("step 1 — minimal KV capacity at peak: {} tokens", report.min_capacity_tokens);
    for (cap, a_ttft, a_tok) in &report.probes {
        println!("  probe capacity={cap:>8} ttft_attain={a_ttft:.3} token_attain={a_tok:.3}");
    }
    println!(
        "step 2 — offline throughput at capacity: {:.1} tok/s (online attain {:.3}/{:.3})",
        report.offline_throughput, report.online_attainment.0, report.online_attainment.1
    );
    Ok(())
}

#[cfg(not(feature = "runtime"))]
fn calibrate(_program: &str, _argv: Vec<String>) -> anyhow::Result<()> {
    anyhow::bail!(
        "built without the `runtime` feature: calibration needs the PJRT \
         backend (add the external `xla` dependency and rebuild with \
         `--features runtime`)"
    )
}

#[cfg(feature = "runtime")]
fn calibrate(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("fit Eq. 6-8 coefficients against the PJRT backend")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("reps", "5", "repetitions per point")
        .opt("out", "", "write fitted config JSON to this path");
    let args = parse_or_usage(&cli, program, argv)?;
    use crate::estimator::{BatchShape, PrefillItem, TimeSample};
    let mut rt = ModelRuntime::load(args.str("artifacts"))?;
    let reps = args.usize("reps").map_err(anyhow::Error::msg)?;
    let mut samples = Vec::new();
    println!("micro-benchmarking prefill buckets…");
    for &chunk in &[16usize, 64] {
        for &context in &[0usize, 32, 64, 128, 192] {
            if context + chunk > rt.manifest.max_seq {
                continue;
            }
            let secs = rt.bench_step(rt.bucket_for(chunk)?, context, reps)?;
            println!("  prefill chunk={chunk:>3} context={context:>4}: {:.2} ms", secs * 1e3);
            // bench_step drives all slots: max_batch prefill items.
            samples.push(TimeSample {
                shape: BatchShape {
                    prefills: vec![PrefillItem { chunk, context }; rt.manifest.max_batch],
                    decode_lens: vec![],
                },
                seconds: secs,
            });
        }
    }
    println!("micro-benchmarking decode…");
    for &context in &[8usize, 32, 64, 128, 192, 240] {
        let secs = rt.bench_step(1, context, reps)?;
        println!("  decode context={context:>4}: {:.2} ms", secs * 1e3);
        samples.push(TimeSample {
            shape: BatchShape {
                prefills: vec![],
                decode_lens: vec![context + 1; rt.manifest.max_batch],
            },
            seconds: secs,
        });
    }
    let prior = SystemConfig::cpu_echolm().time_model;
    let fitted = TimeModel::fit(&samples, prior);
    let err = TimeModel::new(fitted).relative_error(&samples);
    println!(
        "fitted: alpha={:.3e} beta={:.3e} c={:.3e} gamma={:.3e} delta={:.3e} lambda={:.3} \
         (mean rel. err {:.1}%)",
        fitted.alpha, fitted.beta, fitted.c, fitted.gamma, fitted.delta, fitted.lambda,
        err * 100.0
    );
    if !args.str("out").is_empty() {
        let mut cfg = SystemConfig::cpu_echolm();
        cfg.time_model = fitted;
        std::fs::write(args.str("out"), cfg.to_json().pretty())?;
        println!("wrote {}", args.str("out"));
    }
    Ok(())
}

fn trace_gen(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("generate a paper-shaped online arrival trace")
        .opt("horizon", "86400", "horizon, seconds")
        .opt("rate", "1.2", "mean rate, req/s")
        .opt("seed", "42", "rng seed")
        .opt("out", "trace.json", "output path");
    let args = parse_or_usage(&cli, program, argv)?;
    let horizon = args.f64("horizon").map_err(anyhow::Error::msg)?;
    let rate = args.f64("rate").map_err(anyhow::Error::msg)?;
    let seed = args.u64("seed").map_err(anyhow::Error::msg)?;
    let cfg = if (horizon - 86400.0).abs() < 1.0 {
        TraceConfig::paper_24h(rate, seed)
    } else {
        TraceConfig::compressed(horizon, rate, seed)
    };
    let tr = Trace::generate(&cfg);
    tr.save(&args.str("out"))?;
    println!("wrote {} arrivals to {}", tr.len(), args.str("out"));
    Ok(())
}

fn figures_cmd(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("regenerate a paper table/figure")
        .opt(
            "which",
            "all",
            "table1|fig2|fig6|fig7|fig8|fig9|fig10|fig11|ablations|cluster|slo_guard|all",
        )
        .flag("quick", "small horizons (fast, CI-scale)")
        .opt("out", "", "append JSON data to this path");
    let args = parse_or_usage(&cli, program, argv)?;
    let opts = if args.flag("quick") {
        figures::FigureOpts::quick()
    } else {
        figures::FigureOpts::standard()
    };
    let mut out_json = Vec::new();
    let which = args.str("which");
    let want = |name: &str| which == "all" || which == name;
    if want("table1") {
        let (t, j) = figures::table1(opts.seed);
        println!("{t}");
        out_json.push(("table1", j));
    }
    if want("fig2") {
        let (t, j) = figures::fig2(&opts);
        println!("{t}");
        out_json.push(("fig2", j));
    }
    if want("fig6") {
        let (t, j) = figures::fig6(&opts)?;
        println!("{t}");
        out_json.push(("fig6", j));
    }
    if want("fig7") {
        let (t, j) = figures::fig7(&opts)?;
        println!("{t}");
        out_json.push(("fig7", j));
    }
    if want("fig8") {
        let (t, j) = figures::fig8(&opts)?;
        println!("{t}");
        out_json.push(("fig8", j));
    }
    if want("fig9") {
        let (t, j) = figures::fig9(&opts)?;
        println!("{t}");
        out_json.push(("fig9", j));
    }
    if want("fig10") {
        let (t, j) = figures::fig10(&opts)?;
        println!("{t}");
        out_json.push(("fig10", j));
    }
    if want("fig11") {
        let (t, j) = figures::fig11(&opts)?;
        println!("{t}");
        out_json.push(("fig11", j));
    }
    if want("ablations") {
        let (t, j) = figures::ablation_cache(&opts)?;
        println!("{t}");
        out_json.push(("ablation_cache", j));
        let (t, j) = figures::ablation_budget(&opts)?;
        println!("{t}");
        out_json.push(("ablation_budget", j));
    }
    if want("cluster") {
        let (t, j) = figures::fig_cluster(&opts)?;
        println!("{t}");
        out_json.push(("cluster", j));
    }
    if want("slo_guard") {
        let (t, j) = figures::fig_slo_guard(&opts)?;
        println!("{t}");
        out_json.push(("slo_guard", j));
    }
    if !args.str("out").is_empty() {
        let mut obj = Json::obj();
        for (k, v) in out_json {
            obj = obj.set(k, v);
        }
        std::fs::write(args.str("out"), obj.pretty())?;
    }
    Ok(())
}

/// Repo-invariant static analysis (see DESIGN.md "Static analysis").
/// Exits nonzero when any unsuppressed finding remains, so CI can gate on
/// it; `--report` writes the machine-readable `LINT_REPORT.json`.
fn lint_cmd(program: &str, argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new(
        "repo-invariant static analysis: determinism (wall-clock, std-map), \
         zero-alloc hot paths, unwrap hygiene, oracle/gate/doc coverage",
    )
    .opt("root", "", "repo root (default: walk up from the CWD to find rust/src)")
    .opt("report", "", "write the machine-readable report JSON to this path");
    let args = parse_or_usage(&cli, program, argv)?;
    let root = if args.str("root").is_empty() {
        crate::analysis::find_root()?
    } else {
        std::path::PathBuf::from(args.str("root"))
    };
    let report = crate::analysis::lint_repo(&root)?;
    for f in &report.outcome.findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
    }
    let mut count_parts = Vec::new();
    for (rule, n) in report.counts() {
        if n > 0 {
            count_parts.push(format!("{rule}: {n}"));
        }
    }
    if !args.str("report").is_empty() {
        std::fs::write(args.str("report"), report.to_json().pretty())?;
    }
    let n = report.outcome.findings.len();
    println!(
        "echo lint: scanned {} files, {} unsuppressed finding(s){}{}, {} suppressed",
        report.outcome.files_scanned,
        n,
        if count_parts.is_empty() { "" } else { " — " },
        count_parts.join(", "),
        report.outcome.suppressed.len()
    );
    if n > 0 {
        anyhow::bail!("{n} unsuppressed lint finding(s)");
    }
    Ok(())
}

fn dataset_by_name(name: &str) -> anyhow::Result<DatasetSpec> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "sharegpt" => DatasetSpec::sharegpt(),
        "loogle" => DatasetSpec::loogle(),
        "loogle_qa_short" => DatasetSpec::loogle_qa_short(),
        "loogle_qa_long" => DatasetSpec::loogle_qa_long(),
        "toolbench" => DatasetSpec::toolbench(),
        "nextqa" => DatasetSpec::nextqa(),
        other => anyhow::bail!("unknown dataset {other:?}"),
    })
}

#[cfg(not(feature = "runtime"))]
fn smoke() -> anyhow::Result<()> {
    anyhow::bail!(
        "built without the `runtime` feature: no PJRT client to smoke-test \
         (add the external `xla` dependency and rebuild with \
         `--features runtime`)"
    )
}

#[cfg(feature = "runtime")]
fn smoke() -> anyhow::Result<()> {
    let c = xla::PjRtClient::cpu()?;
    println!(
        "echo: pjrt platform={} devices={}",
        c.platform_name(),
        c.device_count()
    );
    Ok(())
}

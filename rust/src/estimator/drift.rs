//! Windowed estimator-drift tracking (PR 10): the gray-failure signal.
//!
//! Engine metrics accumulate est-vs-actual execute-time error
//! *cumulatively* ([`crate::metrics::Metrics::record_estimate`] feeds
//! `est_signed_err_sum` and the error histogram). Gray-failure detection
//! needs the *recent* mean — a replica inside a `Slowdown` window shows a
//! strongly negative signed error (the estimator keeps predicting the
//! healthy time while actuals inflate), but the cumulative bias dilutes it
//! with the whole healthy past. [`DriftWindow`] diffs the cumulative sums
//! against a per-window baseline on the virtual clock: no per-sample
//! storage, no allocation, O(1) per fold.

/// Outcome of folding one tick into a [`DriftWindow`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftSample {
    /// The window has not elapsed yet.
    Open,
    /// The window closed with too few samples to judge.
    Sparse,
    /// The window closed: mean signed relative error over just this
    /// window (negative = actuals exceeded estimates).
    Closed { mean: f64 },
}

/// Cumulative-baseline drift window on the virtual clock.
#[derive(Clone, Copy, Debug)]
pub struct DriftWindow {
    window: f64,
    started: f64,
    base_sum: f64,
    base_count: u64,
}

impl DriftWindow {
    pub fn new(window: f64) -> Self {
        DriftWindow {
            window: window.max(1e-9),
            started: 0.0,
            base_sum: 0.0,
            base_count: 0,
        }
    }

    /// Fold the estimator's cumulative (signed-error sum, sample count) at
    /// virtual time `now`. Once per `window` seconds the baseline rolls
    /// forward and the windowed mean is returned (or `Sparse` when fewer
    /// than `min_samples` landed in the window).
    // lint: hot-path
    pub fn fold(
        &mut self,
        now: f64,
        cum_sum: f64,
        cum_count: u64,
        min_samples: u64,
    ) -> DriftSample {
        if now - self.started < self.window {
            return DriftSample::Open;
        }
        let dn = cum_count.saturating_sub(self.base_count);
        let dsum = cum_sum - self.base_sum;
        self.started = now;
        self.base_sum = cum_sum;
        self.base_count = cum_count;
        if dn < min_samples.max(1) {
            return DriftSample::Sparse;
        }
        DriftSample::Closed {
            mean: dsum / dn as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_roll_and_isolate_recent_drift() {
        let mut w = DriftWindow::new(2.0);
        // Window still open: nothing to judge.
        assert_eq!(w.fold(1.0, -0.5, 4, 2), DriftSample::Open);
        // Closes at 2.0 with 10 samples summing to -1.0 → mean -0.1.
        match w.fold(2.0, -1.0, 10, 2) {
            DriftSample::Closed { mean } => assert!((mean + 0.1).abs() < 1e-12),
            other => panic!("expected Closed, got {other:?}"),
        }
        // Next window sees only the *delta*: 5 new samples summing to
        // -4.0 → mean -0.8, undiluted by the healthy past.
        match w.fold(4.0, -5.0, 15, 2) {
            DriftSample::Closed { mean } => assert!((mean + 0.8).abs() < 1e-12),
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn sparse_windows_are_not_judged() {
        let mut w = DriftWindow::new(1.0);
        assert_eq!(w.fold(1.0, -9.0, 3, 8), DriftSample::Sparse);
        // The baseline still rolled: the next window diffs from here.
        match w.fold(2.0, -9.0, 11, 8) {
            DriftSample::Closed { mean } => assert_eq!(mean, 0.0),
            other => panic!("expected Closed, got {other:?}"),
        }
    }
}

//! Estimation toolkits (paper §5): batch execution-time model (Eqs. 6-8)
//! with micro-benchmark coefficient fitting, and the bursty-online memory
//! predictor (§5.3). The resource/throughput deployer simulator (§5.4)
//! composes these with the engine and lives in [`crate::sim`].

pub mod drift;
pub mod memory;
pub mod time_model;

pub use drift::{DriftSample, DriftWindow};
pub use memory::MemoryPredictor;
pub use time_model::{BatchShape, PrefillItem, TimeModel, TimeSample, TrialShape, TrialUndo};

//! Batch execution-time estimator (paper §5.2).
//!
//! Eq. 6:  Time_prefill = max(α·l² + β·l, c)          (one prefill request)
//! Eq. 7:  Time_decode  = γ·max(L) + δ·mean(L)        (decode batch)
//! Eq. 8:  Time_batch   = λ·max(Tp, Td) + (1-λ)·min(Tp, Td)
//!
//! Extension for chunked prefill (§2.1): a chunk of width `w` over an
//! existing context of `o` tokens does the *incremental* quadratic
//! attention work (o+w)² − o² = w² + 2wo, so its Eq. 6 feature is
//! (w² + 2wo); with o = 0 this reduces exactly to the paper's form.
//!
//! Coefficients are fitted before deployment from micro-benchmarks
//! (`TimeModel::fit`) via ordinary least squares.

use crate::config::TimeModelConfig;
use crate::utils::stats::least_squares;

/// One prefill item in a batch: chunk width over an existing context.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefillItem {
    pub chunk: usize,
    pub context: usize,
}

impl PrefillItem {
    /// Quadratic-work feature (w² + 2wo) of Eq. 6's extension.
    pub fn quad_feature(&self) -> f64 {
        let w = self.chunk as f64;
        let o = self.context as f64;
        w * w + 2.0 * w * o
    }
}

/// The shape of an iteration batch — everything Eq. 6-8 need.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchShape {
    pub prefills: Vec<PrefillItem>,
    /// Context length (KV read span) per decode item.
    pub decode_lens: Vec<usize>,
}

impl BatchShape {
    pub fn is_empty(&self) -> bool {
        self.prefills.is_empty() && self.decode_lens.is_empty()
    }

    pub fn total_tokens(&self) -> usize {
        self.prefills.iter().map(|p| p.chunk).sum::<usize>() + self.decode_lens.len()
    }
}

/// A [`BatchShape`] with incrementally maintained Eq. 6-8 aggregates, for
/// O(1) trial scoring in the scheduler's plan search (§4.1).
///
/// The scheduler's candidate loop used to clone the whole shape per trial
/// and let `batch_time` re-scan every item. `TrialShape` instead mutates a
/// single shape in place: `push_*` appends an item and updates the
/// aggregates (prefill seconds, decode sum/max), returning a [`TrialUndo`]
/// that restores the *exact* previous aggregate values. Undo saves the
/// prior floats rather than subtracting, so a push/undo pair is a perfect
/// no-op and committed batches accumulate in append order — which makes
/// [`TimeModel::batch_time_inc`] bit-identical to recomputing
/// `batch_time(shape)` from scratch (left-to-right summation, exact
/// integer decode sums). The equivalence tests pin this down.
///
/// Discipline: undo is LIFO — only the most recent un-undone push may be
/// undone.
#[derive(Clone, Debug, Default)]
pub struct TrialShape {
    shape: BatchShape,
    /// Σ `prefill_item(i)` over `shape.prefills`, accumulated in push order.
    prefill_secs: f64,
    /// Σ `shape.decode_lens` (exact).
    decode_sum: u64,
    /// max(`shape.decode_lens`) (0 when empty).
    decode_max: usize,
}

/// Saved aggregate state that reverses one `TrialShape::push_*`.
#[derive(Clone, Copy, Debug)]
pub enum TrialUndo {
    Decode { prev_max: usize },
    Prefill { prev_secs: f64 },
}

impl TrialShape {
    /// Rebuild a trial view from an existing shape (aggregates recomputed
    /// in item order, so `batch_time_inc` matches `batch_time(&shape)`).
    pub fn from_shape(tm: &TimeModel, shape: BatchShape) -> Self {
        let mut t = TrialShape::default();
        for &item in &shape.prefills {
            let _ = t.push_prefill(tm, item);
        }
        for &len in &shape.decode_lens {
            let _ = t.push_decode(len);
        }
        debug_assert_eq!(t.shape, shape);
        t
    }

    /// Rebuild an *empty* trial reusing `shape`'s heap allocations. The
    /// engine's step loop recycles the previous plan's vectors through
    /// here, so a steady-state iteration never reallocates the shape.
    pub fn recycled(mut shape: BatchShape) -> Self {
        shape.prefills.clear();
        shape.decode_lens.clear();
        TrialShape {
            shape,
            prefill_secs: 0.0,
            decode_sum: 0,
            decode_max: 0,
        }
    }

    /// Append one decode item of context length `len`.
    pub fn push_decode(&mut self, len: usize) -> TrialUndo {
        let prev_max = self.decode_max;
        self.shape.decode_lens.push(len);
        self.decode_sum += len as u64;
        self.decode_max = self.decode_max.max(len);
        TrialUndo::Decode { prev_max }
    }

    /// Append one prefill chunk.
    pub fn push_prefill(&mut self, tm: &TimeModel, item: PrefillItem) -> TrialUndo {
        let prev_secs = self.prefill_secs;
        self.shape.prefills.push(item);
        self.prefill_secs = prev_secs + tm.prefill_item(item);
        TrialUndo::Prefill { prev_secs }
    }

    /// Reverse the most recent un-undone push (LIFO).
    pub fn undo(&mut self, u: TrialUndo) {
        match u {
            TrialUndo::Decode { prev_max } => {
                let len = self
                    .shape
                    .decode_lens
                    .pop()
                    // lint: allow-unwrap(undo tokens are handed out by push; LIFO pairing)
                    .expect("TrialShape::undo without a matching decode push");
                self.decode_sum -= len as u64;
                self.decode_max = prev_max;
            }
            TrialUndo::Prefill { prev_secs } => {
                self.shape
                    .prefills
                    .pop()
                    // lint: allow-unwrap(undo tokens are handed out by push; LIFO pairing)
                    .expect("TrialShape::undo without a matching prefill push");
                self.prefill_secs = prev_secs;
            }
        }
    }

    pub fn shape(&self) -> &BatchShape {
        &self.shape
    }

    pub fn into_shape(self) -> BatchShape {
        self.shape
    }
}

/// A measured (shape, seconds) pair from micro-benchmarks.
#[derive(Clone, Debug)]
pub struct TimeSample {
    pub shape: BatchShape,
    pub seconds: f64,
}

/// Eq. 6-8 evaluator + fitter.
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    pub cfg: TimeModelConfig,
}

impl TimeModel {
    pub fn new(cfg: TimeModelConfig) -> Self {
        TimeModel { cfg }
    }

    /// Eq. 6 (chunk-extended): one prefill item.
    pub fn prefill_item(&self, item: PrefillItem) -> f64 {
        let t = self.cfg.alpha * item.quad_feature() + self.cfg.beta * item.chunk as f64;
        t.max(self.cfg.c)
    }

    /// Prefill part of a batch (items processed one by one, §5.2).
    pub fn prefill_time(&self, items: &[PrefillItem]) -> f64 {
        items.iter().map(|&i| self.prefill_item(i)).sum()
    }

    /// Eq. 7: decode part of a batch.
    pub fn decode_time(&self, lens: &[usize]) -> f64 {
        if lens.is_empty() {
            return 0.0;
        }
        // lint: allow-unwrap(is_empty was checked above)
        let max = lens.iter().copied().max().unwrap() as f64;
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        self.cfg.gamma * max + self.cfg.delta * mean
    }

    /// Eq. 8: full batch.
    pub fn batch_time(&self, shape: &BatchShape) -> f64 {
        let tp = self.prefill_time(&shape.prefills);
        let td = self.decode_time(&shape.decode_lens);
        match (tp > 0.0, td > 0.0) {
            (false, false) => 0.0,
            (true, false) => tp,
            (false, true) => td.max(self.cfg.c),
            (true, true) => {
                self.cfg.lambda * tp.max(td) + (1.0 - self.cfg.lambda) * tp.min(td)
            }
        }
    }

    /// Eq. 8 from a trial's O(1) aggregates. Bit-identical to
    /// `batch_time(trial.shape())`: the prefill sum accumulates per-item
    /// times in the same left-to-right order `prefill_time` folds them, and
    /// the decode terms use the exact integer sum/max.
    pub fn batch_time_inc(&self, t: &TrialShape) -> f64 {
        let tp = t.prefill_secs;
        let td = if t.shape.decode_lens.is_empty() {
            0.0
        } else {
            let max = t.decode_max as f64;
            let mean = t.decode_sum as f64 / t.shape.decode_lens.len() as f64;
            self.cfg.gamma * max + self.cfg.delta * mean
        };
        match (tp > 0.0, td > 0.0) {
            (false, false) => 0.0,
            (true, false) => tp,
            (false, true) => td.max(self.cfg.c),
            (true, true) => {
                self.cfg.lambda * tp.max(td) + (1.0 - self.cfg.lambda) * tp.min(td)
            }
        }
    }

    /// Fit α, β, c, γ, δ, λ from micro-benchmark samples. Requires
    /// prefill-only, decode-only, and mixed samples; falls back to the
    /// prior config for any family with too few samples.
    pub fn fit(samples: &[TimeSample], prior: TimeModelConfig) -> TimeModelConfig {
        let mut cfg = prior;

        // ---- prefill-only: items run one by one, so a batch's time is
        // α·Σq + β·Σw (+ per-item floor, folded out by fitting sums) ------
        let pre: Vec<&TimeSample> = samples
            .iter()
            .filter(|s| s.shape.decode_lens.is_empty() && !s.shape.prefills.is_empty())
            .collect();
        if pre.len() >= 4 {
            let rows: Vec<Vec<f64>> = pre
                .iter()
                .map(|s| {
                    let q: f64 = s.shape.prefills.iter().map(|i| i.quad_feature()).sum();
                    let w: f64 = s.shape.prefills.iter().map(|i| i.chunk as f64).sum();
                    vec![q, w]
                })
                .collect();
            let y: Vec<f64> = pre.iter().map(|s| s.seconds).collect();
            if let Some(beta) = least_squares(&rows, &y) {
                if beta.iter().all(|b| b.is_finite()) {
                    if beta[0] >= 0.0 {
                        cfg.alpha = beta[0];
                        cfg.beta = beta[1].max(0.0);
                    } else {
                        // Quadratic term not identifiable (e.g. a backend
                        // whose attention scans a fixed-size slab): refit
                        // the linear term alone with alpha pinned to 0.
                        let rows1: Vec<Vec<f64>> =
                            rows.iter().map(|r| vec![r[1]]).collect();
                        if let Some(b1) = least_squares(&rows1, &y) {
                            if b1[0].is_finite() {
                                cfg.alpha = 0.0;
                                cfg.beta = b1[0].max(0.0);
                            }
                        }
                    }
                }
            }
            // Floor: the fastest per-item prefill observed bounds it.
            let min_t = pre
                .iter()
                .map(|s| s.seconds / s.shape.prefills.len() as f64)
                .fold(f64::INFINITY, f64::min);
            cfg.c = min_t.min(cfg.c.max(1e-6));
        }

        // ---- decode-only: t = γ·max + δ·mean ---------------------------
        let dec: Vec<&TimeSample> = samples
            .iter()
            .filter(|s| s.shape.prefills.is_empty() && !s.shape.decode_lens.is_empty())
            .collect();
        if dec.len() >= 4 {
            let rows: Vec<Vec<f64>> = dec
                .iter()
                .map(|s| {
                    let lens = &s.shape.decode_lens;
                    // lint: allow-unwrap(dec samples all carry at least one decode)
                    let max = lens.iter().copied().max().unwrap() as f64;
                    let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
                    vec![max, mean]
                })
                .collect();
            let y: Vec<f64> = dec.iter().map(|s| s.seconds).collect();
            let sse = |g: f64, d: f64| -> f64 {
                rows.iter()
                    .zip(&y)
                    .map(|(r, &t)| {
                        let p = g * r[0] + d * r[1];
                        (p - t) * (p - t)
                    })
                    .sum()
            };
            let mut best: Option<(f64, f64, f64)> = None; // (sse, gamma, delta)
            if let Some(beta) = least_squares(&rows, &y) {
                if beta.iter().all(|b| b.is_finite() && *b >= 0.0) {
                    best = Some((sse(beta[0], beta[1]), beta[0], beta[1]));
                }
            }
            // Fallback for collinear designs (uniform batch lengths make
            // max == mean): single combined coefficient on the mean.
            let rows1: Vec<Vec<f64>> = rows.iter().map(|r| vec![r[1]]).collect();
            if let Some(b1) = least_squares(&rows1, &y) {
                if b1[0].is_finite() && b1[0] >= 0.0 {
                    let cand = (sse(0.0, b1[0]), 0.0, b1[0]);
                    if best.map_or(true, |b| cand.0 < b.0) {
                        best = Some(cand);
                    }
                }
            }
            if let Some((_, g, d)) = best {
                cfg.gamma = g;
                cfg.delta = d;
            }
        }

        // ---- mixed: λ from t = λ·max + (1-λ)·min ------------------------
        let model = TimeModel::new(cfg);
        let mut lambdas = Vec::new();
        for s in samples {
            if s.shape.prefills.is_empty() || s.shape.decode_lens.is_empty() {
                continue;
            }
            let tp = model.prefill_time(&s.shape.prefills);
            let td = model.decode_time(&s.shape.decode_lens);
            let (hi, lo) = (tp.max(td), tp.min(td));
            if hi - lo > 1e-9 {
                lambdas.push(((s.seconds - lo) / (hi - lo)).clamp(0.0, 1.5));
            }
        }
        if lambdas.len() >= 2 {
            cfg.lambda =
                (lambdas.iter().sum::<f64>() / lambdas.len() as f64).clamp(0.0, 1.0);
        }
        cfg
    }

    /// Mean relative error of the model against samples (calibration QA,
    /// reported by `echo calibrate` and EXPERIMENTS.md).
    pub fn relative_error(&self, samples: &[TimeSample]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        samples
            .iter()
            .map(|s| {
                let est = self.batch_time(&s.shape);
                (est - s.seconds).abs() / s.seconds.max(1e-9)
            })
            .sum::<f64>()
            / samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TimeModelConfig {
        TimeModelConfig {
            alpha: 1e-8,
            beta: 1e-4,
            c: 5e-3,
            gamma: 1e-5,
            delta: 6e-5,
            lambda: 0.8,
        }
    }

    #[test]
    fn prefill_floor_applies() {
        let m = TimeModel::new(cfg());
        let tiny = m.prefill_item(PrefillItem { chunk: 1, context: 0 });
        assert_eq!(tiny, 5e-3);
        let big = m.prefill_item(PrefillItem { chunk: 8192, context: 0 });
        assert!(big > 0.8 && big < 2.0, "8k prefill ≈ 1s on A100: {big}");
    }

    #[test]
    fn chunk_extension_reduces_to_eq6() {
        let m = TimeModel::new(cfg());
        let full = m.prefill_item(PrefillItem { chunk: 1000, context: 0 });
        // α·l² + β·l directly
        let direct = 1e-8 * 1e6 + 1e-4 * 1000.0;
        assert!((full - direct).abs() < 1e-12);
    }

    #[test]
    fn chunked_sum_exceeds_oneshot_quadratic_consistency() {
        // Sum of incremental chunk features telescopes to the full square.
        let m = TimeModel::new(TimeModelConfig { c: 0.0, ..cfg() });
        let oneshot = m.prefill_item(PrefillItem { chunk: 2048, context: 0 });
        let chunked: f64 = (0..4)
            .map(|i| m.prefill_item(PrefillItem { chunk: 512, context: 512 * i }))
            .sum();
        assert!((oneshot - chunked).abs() < 1e-9, "{oneshot} vs {chunked}");
    }

    #[test]
    fn decode_pooling() {
        let m = TimeModel::new(cfg());
        let t = m.decode_time(&[100, 200, 300]);
        assert!((t - (1e-5 * 300.0 + 6e-5 * 200.0)).abs() < 1e-12);
        assert_eq!(m.decode_time(&[]), 0.0);
    }

    #[test]
    fn batch_combines_between_max_and_sum() {
        let m = TimeModel::new(cfg());
        let shape = BatchShape {
            prefills: vec![PrefillItem { chunk: 2048, context: 0 }],
            decode_lens: vec![500; 16],
        };
        let tp = m.prefill_time(&shape.prefills);
        let td = m.decode_time(&shape.decode_lens);
        let tb = m.batch_time(&shape);
        assert!(tb >= tp.max(td) * 0.999 - (1.0 - 0.8) * (tp.max(td) - tp.min(td)));
        assert!(tb <= tp + td);
        assert!(tb >= tp.min(td));
    }

    #[test]
    fn fit_recovers_synthetic_coefficients() {
        let truth = TimeModelConfig {
            alpha: 3e-8,
            beta: 2e-4,
            c: 1e-3,
            gamma: 2e-5,
            delta: 8e-5,
            lambda: 0.7,
        };
        let tm = TimeModel::new(truth);
        let mut samples = Vec::new();
        for l in [64usize, 128, 256, 512, 1024, 2048, 4096] {
            for o in [0usize, 256, 1024] {
                let shape = BatchShape {
                    prefills: vec![PrefillItem { chunk: l, context: o }],
                    decode_lens: vec![],
                };
                samples.push(TimeSample { seconds: tm.batch_time(&shape), shape });
            }
        }
        for n in [1usize, 4, 16, 64] {
            for len in [64usize, 512, 2048] {
                let shape = BatchShape {
                    prefills: vec![],
                    decode_lens: (0..n).map(|i| len + i * 7).collect(),
                };
                samples.push(TimeSample { seconds: tm.batch_time(&shape), shape });
            }
        }
        for l in [256usize, 1024] {
            for n in [4usize, 32] {
                let shape = BatchShape {
                    prefills: vec![PrefillItem { chunk: l, context: 0 }],
                    decode_lens: vec![800; n],
                };
                samples.push(TimeSample { seconds: tm.batch_time(&shape), shape });
            }
        }
        let fitted = TimeModel::fit(&samples, cfg());
        assert!((fitted.alpha - truth.alpha).abs() / truth.alpha < 0.05, "alpha {}", fitted.alpha);
        assert!((fitted.beta - truth.beta).abs() / truth.beta < 0.05);
        assert!((fitted.gamma - truth.gamma).abs() / truth.gamma < 0.05);
        assert!((fitted.delta - truth.delta).abs() / truth.delta < 0.05);
        assert!((fitted.lambda - truth.lambda).abs() < 0.05);
        let err = TimeModel::new(fitted).relative_error(&samples);
        assert!(err < 0.05, "mean relative error {err}");
    }

    #[test]
    fn fit_with_no_samples_keeps_prior() {
        let fitted = TimeModel::fit(&[], cfg());
        assert_eq!(fitted.alpha, cfg().alpha);
        assert_eq!(fitted.lambda, cfg().lambda);
    }

    #[test]
    fn recycled_trial_reuses_capacity_and_resets_aggregates() {
        let m = TimeModel::new(cfg());
        let mut t = TrialShape::default();
        let _ = t.push_decode(100);
        let _ = t.push_prefill(&m, PrefillItem { chunk: 64, context: 0 });
        let shape = t.into_shape();
        let cap = (shape.prefills.capacity(), shape.decode_lens.capacity());
        let t2 = TrialShape::recycled(shape);
        assert!(t2.shape().is_empty());
        assert_eq!(m.batch_time_inc(&t2), 0.0);
        let s2 = t2.into_shape();
        assert_eq!((s2.prefills.capacity(), s2.decode_lens.capacity()), cap);
    }

    #[test]
    fn trial_shape_matches_batch_time_bit_exactly() {
        let m = TimeModel::new(cfg());
        // Deterministic pseudo-random push/undo walk.
        let mut state = 0x1234_5678_u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        let mut trial = TrialShape::default();
        for _ in 0..500 {
            let r = next() % 10;
            if r < 4 {
                let _ = trial.push_decode(1 + next() % 4096);
            } else if r < 8 {
                let _ = trial.push_prefill(
                    &m,
                    PrefillItem {
                        chunk: 1 + next() % 512,
                        context: next() % 8192,
                    },
                );
            } else {
                // Trial that gets rejected: push, score, undo.
                let u = if r == 8 {
                    trial.push_decode(1 + next() % 4096)
                } else {
                    trial.push_prefill(
                        &m,
                        PrefillItem {
                            chunk: 1 + next() % 512,
                            context: next() % 8192,
                        },
                    )
                };
                let _ = m.batch_time_inc(&trial);
                trial.undo(u);
            }
            let inc = m.batch_time_inc(&trial);
            let full = m.batch_time(trial.shape());
            assert_eq!(
                inc.to_bits(),
                full.to_bits(),
                "incremental {} != recomputed {} after {} items",
                inc,
                full,
                trial.shape().prefills.len() + trial.shape().decode_lens.len()
            );
        }
        // from_shape rebuild agrees too.
        let rebuilt = TrialShape::from_shape(&m, trial.shape().clone());
        assert_eq!(
            m.batch_time_inc(&rebuilt).to_bits(),
            m.batch_time_inc(&trial).to_bits()
        );
    }
}

//! Memory-consumption predictor for bursty online tasks (paper §5.3).
//!
//! Observes the online tasks' KV footprint over a trailing window (the
//! paper uses the past hour), assumes a normal distribution, and predicts
//! μ + k·σ (k = 2 ≈ 95% coverage) as the reserve the KV cache manager
//! should hold back for upcoming online bursts. Re-evaluated every
//! `update_period` seconds, not every iteration.

use crate::config::PredictorConfig;
use crate::utils::stats::SlidingWindow;

#[derive(Clone, Debug)]
pub struct MemoryPredictor {
    cfg: PredictorConfig,
    window: SlidingWindow,
    last_update: f64,
    current_reserve: f64,
    /// (time, predicted, actual) — Fig. 11's series.
    pub history: Vec<(f64, f64, f64)>,
}

impl MemoryPredictor {
    pub fn new(cfg: PredictorConfig) -> Self {
        MemoryPredictor {
            window: SlidingWindow::new(cfg.history_horizon),
            cfg,
            last_update: f64::NEG_INFINITY,
            current_reserve: 0.0,
            history: Vec::new(),
        }
    }

    /// Record the current online KV footprint (tokens) at time `t`.
    pub fn observe(&mut self, t: f64, online_kv_tokens: f64) {
        self.window.push(t, online_kv_tokens);
    }

    /// Predicted online KV demand (tokens) = μ + k·σ over the window.
    /// Updates only once per `update_period`; otherwise returns the cached
    /// prediction (cheap to call every iteration).
    pub fn reserve_tokens(&mut self, t: f64) -> f64 {
        if t - self.last_update >= self.cfg.update_period {
            self.last_update = t;
            let predicted = self.window.mean_plus_k_sigma(self.cfg.k_sigma);
            self.current_reserve = predicted;
            let actual = self
                .window
                .mean_plus_k_sigma(0.0); // current mean as the "actual" level
            self.history.push((t, predicted, actual));
        }
        self.current_reserve
    }

    /// Fraction of observations covered by the prediction in hindsight
    /// (Fig. 11 quality number; ≈0.95 for k=2 under normality).
    pub fn coverage(&self, observations: &[(f64, f64)]) -> f64 {
        if observations.is_empty() || self.history.is_empty() {
            return 1.0;
        }
        let mut covered = 0usize;
        for &(t, v) in observations {
            // prediction active at time t = last history entry before t
            let pred = self
                .history
                .iter()
                .rev()
                .find(|&&(ht, _, _)| ht <= t)
                .map(|&(_, p, _)| p)
                .unwrap_or(f64::INFINITY);
            if v <= pred {
                covered += 1;
            }
        }
        covered as f64 / observations.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PredictorConfig {
        PredictorConfig {
            history_horizon: 100.0,
            update_period: 10.0,
            k_sigma: 2.0,
        }
    }

    #[test]
    fn predicts_mu_plus_2sigma() {
        let mut p = MemoryPredictor::new(cfg());
        // alternating 100/200 -> μ=150, σ=50 -> reserve 250
        for i in 0..100 {
            p.observe(i as f64, if i % 2 == 0 { 100.0 } else { 200.0 });
        }
        let r = p.reserve_tokens(100.0);
        assert!((r - 250.0).abs() < 1.0, "r={r}");
    }

    #[test]
    fn update_period_caches() {
        let mut p = MemoryPredictor::new(cfg());
        for i in 0..50 {
            p.observe(i as f64, 100.0);
        }
        let r1 = p.reserve_tokens(50.0);
        // Shift the data hard; before the period elapses the cached value
        // must be returned.
        for i in 50..55 {
            p.observe(i as f64, 10_000.0);
        }
        let r2 = p.reserve_tokens(55.0);
        assert_eq!(r1, r2);
        let r3 = p.reserve_tokens(61.0);
        assert!(r3 > r2);
    }

    #[test]
    fn window_forgets_old_peaks() {
        let mut p = MemoryPredictor::new(cfg());
        for i in 0..50 {
            p.observe(i as f64, 5000.0); // old peak
        }
        for i in 50..300 {
            p.observe(i as f64, 100.0); // calm hours
        }
        let r = p.reserve_tokens(300.0);
        assert!(r < 200.0, "old peak must have aged out, r={r}");
    }

    #[test]
    fn coverage_on_stable_series() {
        let mut p = MemoryPredictor::new(cfg());
        let mut obs = Vec::new();
        let mut x = 0u64;
        for i in 0..500 {
            // pseudo-noise without rand: simple LCG
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let noise = ((x >> 33) as f64 / 2f64.powi(31) - 0.5) * 40.0;
            let v = 150.0 + noise;
            p.observe(i as f64, v);
            let _ = p.reserve_tokens(i as f64);
            if i > 100 {
                obs.push((i as f64, v));
            }
        }
        assert!(p.coverage(&obs) > 0.9);
    }
}

//! One cluster member: an `Engine<SimBackend>` plus the load/KV-pressure
//! digest it publishes to the router each sync step.

use crate::config::SystemConfig;
use crate::core::{ReqState, TaskClass};
use crate::engine::{sim::SimBackend, Engine};
use crate::estimator::TimeModel;

use super::health::ReplicaHealth;
use super::router::PrefixSummary;

/// Per-replica backend seed: replica 0 keeps the base seed unchanged, so a
/// single-replica cluster replays exactly like a bare engine (the N=1
/// equivalence the router tests pin down).
pub fn replica_seed(base: u64, id: usize) -> u64 {
    base ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Cheap snapshot of a replica's load, published to the router each sync
/// quantum. Everything the dispatch decision needs, nothing engine-internal.
#[derive(Clone, Debug)]
pub struct LoadDigest {
    pub replica: usize,
    /// Replica virtual clock at publication (informational: telemetry and
    /// future staleness weighting; dispatch does not read it today).
    pub clock: f64,
    /// Online requests accepted but not yet running.
    pub queued_online: usize,
    pub running_online: usize,
    pub running_offline: usize,
    /// Pending offline requests in the pool (work-stealing signal).
    pub pool_backlog: usize,
    /// Online prefill tokens still to compute (queued prompts + running
    /// prefill remainders) — the estimator's queue-delay feature.
    pub pending_prefill_tokens: usize,
    /// Online-allocatable KV headroom in blocks (free + evictable).
    pub free_blocks: usize,
    pub block_size: usize,
    /// Draining replicas take no new online work.
    pub draining: bool,
    /// Gray-failure ladder says route around this replica (PR 10):
    /// Probation and Quarantined replicas take no new online work and are
    /// skipped by work-stealing. Always `false` when health is disarmed.
    pub degraded: bool,
    /// Prefix summary: resident content keys, full or as churn since the
    /// previous publication (see [`PrefixSummary`]).
    pub summary: PrefixSummary,
}

pub struct Replica {
    pub id: usize,
    pub engine: Engine<SimBackend>,
    /// Scale-down in progress: no new work, finish what is running.
    pub draining: bool,
    /// Sim-time this replica joined the fleet (autoscaling timeline).
    pub spawned_at: f64,
    /// Gray-failure ladder slot (PR 10); `None` when health is disarmed.
    /// A respawned replica gets a fresh slot — quarantine never sticks to
    /// the successor.
    pub health: Option<ReplicaHealth>,
    /// Whether the router holds an untruncated full summary from us — the
    /// precondition for publishing deltas.
    published_full: bool,
}

impl Replica {
    pub fn new(id: usize, cfg: SystemConfig, jitter: f64, spawned_at: f64) -> Self {
        let seed = replica_seed(cfg.seed, id);
        let backend = SimBackend::new(TimeModel::new(cfg.time_model), seed, jitter);
        let mut engine = Engine::new(cfg, backend);
        // Delta-digest protocol: record key churn from the very first block.
        engine.kv.enable_key_churn();
        Replica {
            id,
            engine,
            draining: false,
            spawned_at,
            health: None,
            published_full: false,
        }
    }

    /// Publish the current load digest. `summary_cap` bounds the prefix
    /// summary size (the router's per-replica index memory).
    ///
    /// The first publication (and any publication while the cache exceeds
    /// `summary_cap`) ships a full summary; afterwards only the key churn
    /// since the previous digest is shipped, so a sync quantum costs
    /// O(churn) rather than O(cache size). Load counters scan only the
    /// engine's live (unfinished) requests, not the whole store history.
    pub fn digest(&mut self, summary_cap: usize) -> LoadDigest {
        let e = &self.engine;
        let mut queued_online = 0usize;
        let mut running_online = 0usize;
        let mut running_offline = 0usize;
        let mut pending_prefill_tokens = 0usize;
        for r in e.live_requests() {
            match (r.state, r.class) {
                (ReqState::Running, TaskClass::Online) => {
                    running_online += 1;
                    if r.in_prefill() {
                        pending_prefill_tokens += r.remaining_prefill();
                    }
                }
                (ReqState::Running, TaskClass::Offline) => running_offline += 1,
                (ReqState::Queued, TaskClass::Online) => {
                    queued_online += 1;
                    pending_prefill_tokens += r.seq_len();
                }
                _ => {}
            }
        }
        let avail = e.kv.availability();
        let digest_base = LoadDigest {
            replica: self.id,
            clock: e.clock,
            queued_online,
            running_online,
            running_offline,
            pool_backlog: e.pool.len(),
            pending_prefill_tokens,
            free_blocks: avail.for_online(),
            block_size: e.cfg.cache.block_size,
            draining: self.draining,
            degraded: self.health.as_ref().is_some_and(|h| h.degraded()),
            summary: PrefixSummary::Full(Vec::new()),
        };
        let truncating = self.engine.kv.cached_key_count() > summary_cap;
        let summary = if self.published_full && !truncating {
            match self.engine.kv.take_key_churn() {
                Some((added, removed)) => PrefixSummary::Delta { added, removed },
                None => PrefixSummary::Full(self.engine.kv.cached_key_sample(summary_cap)),
            }
        } else {
            // Drain the churn log first so the next delta starts exactly at
            // this snapshot, then sample (no mutation in between).
            let _ = self.engine.kv.take_key_churn();
            self.published_full = !truncating;
            PrefixSummary::Full(self.engine.kv.cached_key_sample(summary_cap))
        };
        LoadDigest {
            summary,
            ..digest_base
        }
    }

    /// True when nothing is running or pending — a draining replica in this
    /// state can retire. Inert store entries left behind by work-stealing
    /// (`ReqState::Queued` offline orphans) do not block retirement; only
    /// live (unfinished, un-stolen) requests are scanned.
    pub fn is_idle(&self) -> bool {
        let e = &self.engine;
        e.backlog_online() == 0
            && e.pool.is_empty()
            && e.live_requests()
                .all(|r| !matches!(r.state, ReqState::Running | ReqState::Preempted))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{PromptSpec, Request};

    #[test]
    fn replica_zero_keeps_base_seed() {
        assert_eq!(replica_seed(42, 0), 42);
        assert_ne!(replica_seed(42, 1), 42);
        assert_ne!(replica_seed(42, 1), replica_seed(42, 2));
    }

    #[test]
    fn digest_tracks_submissions() {
        let mut rep = Replica::new(0, SystemConfig::a100_llama8b(), 0.0, 0.0);
        assert!(rep.is_idle());
        let d = rep.digest(usize::MAX);
        assert_eq!(d.queued_online, 0);
        assert_eq!(d.pool_backlog, 0);
        assert!(d.free_blocks > 0);
        assert!(
            matches!(d.summary, PrefixSummary::Full(_)),
            "first publication must be a full summary"
        );

        let id = rep.engine.store.fresh_id();
        rep.engine.submit_online(Request::new(
            id,
            TaskClass::Online,
            1.0,
            PromptSpec::sim(200, None),
            8,
        ));
        let id2 = rep.engine.store.fresh_id();
        rep.engine.submit_offline(Request::new(
            id2,
            TaskClass::Offline,
            0.0,
            PromptSpec::sim(300, None),
            8,
        ));
        let d = rep.digest(usize::MAX);
        assert_eq!(d.queued_online, 1);
        assert_eq!(d.pending_prefill_tokens, 200);
        assert_eq!(d.pool_backlog, 1);
        assert!(!rep.is_idle());

        rep.engine.run().unwrap();
        assert!(rep.is_idle());
        let d = rep.digest(usize::MAX);
        assert_eq!(d.queued_online + d.running_online + d.running_offline, 0);
        // Finished work leaves reusable cache behind; after the initial
        // full summary the digest ships it as added-key churn.
        match d.summary {
            PrefixSummary::Delta { ref added, .. } => {
                assert!(!added.is_empty(), "run must have cached new keys")
            }
            PrefixSummary::Full(_) => panic!("steady-state digest must be a delta"),
        }
        assert_eq!(
            rep.engine.kv.take_key_churn(),
            Some((vec![], vec![])),
            "digest must drain the churn log"
        );
    }
}

//! Cluster router: prefix-affinity dispatch with estimator tie-breaking.
//!
//! The router owns a cluster-level radix index built from replica prefix
//! summaries. Block content keys are chain hashes (each key commits to its
//! entire prefix — see `PromptSpec::content_key`), so the index can store a
//! flat per-replica key set and a membership walk down a request's key
//! sequence is exactly a radix-tree descent: the walk stops at the first
//! key the replica does not hold, and its length is the cached depth.
//!
//! Dispatch rule for an online arrival:
//!   1. prefix affinity — the replica with the deepest cached prefix wins,
//!      *unless* admitting the request there would exceed its online
//!      KV headroom (capacity veto);
//!   2. ties (typically depth 0) break on estimator-predicted latency
//!      (Eq. 6-8 over the digest's queue state), then on replica id;
//!   3. if no replica has headroom, the least-predicted-latency replica
//!      takes the overflow (its scheduler will preempt offline work).
//!
//! Replicas flagged `degraded` by the gray-failure monitor (PR 10) are
//! excluded from dispatch and work-stealing like draining ones; in the
//! nobody-else-left fallback their predicted latency is inflated by
//! [`DEGRADED_PENALTY`] so a healthy draining replica still wins.

use std::collections::BTreeMap;

use crate::core::PromptSpec;
use crate::estimator::{PrefillItem, TimeModel};
use crate::utils::hash::{FxHashMap, FxHashSet};

use super::replica::LoadDigest;

/// Predicted-latency multiplier for degraded replicas in the last-resort
/// dispatch path (every non-degraded, non-draining replica is preferred
/// outright; this only orders the fallback among the walking wounded).
pub const DEGRADED_PENALTY: f64 = 4.0;

/// Leading content keys of `prompt` that are owner-independent (shared
/// across requests of the same prefix group), probed with owner 0. Keys of
/// private-tail blocks are excluded so affinity depth never overestimates.
///
/// Thin copying wrapper over the interned [`PromptSpec::affinity_keys`]
/// (kept for callers that want an owned vector); the router itself uses
/// the interned slice directly and never re-hashes a prompt it has seen.
pub fn affinity_keys(prompt: &PromptSpec, block_size: usize) -> Vec<u128> {
    prompt.affinity_keys(block_size).to_vec()
}

/// A replica's prefix summary as shipped in a [`LoadDigest`].
///
/// `Full` replaces the router's view of the replica; `Delta` carries only
/// the keys cached/evicted since the replica's previous summary, so a sync
/// quantum costs O(churn) instead of O(cache size). The two protocols
/// converge to identical router state at every sync boundary (equivalence
/// property test); replicas fall back to `Full` on first publication and
/// whenever the summary cap would truncate (a truncated delta base would
/// desync).
#[derive(Clone, Debug)]
pub enum PrefixSummary {
    Full(Vec<u128>),
    Delta { added: Vec<u128>, removed: Vec<u128> },
}

/// Cluster-level radix index over replica prefix summaries. Chain-hashed
/// keys make the per-replica key set an implicit radix tree (see module
/// docs); `cached_depth` is the descent. Leaf sets use the deterministic
/// fast hasher (`utils::hash`): the descent probes one u128 per level, so
/// per-key hashing cost is the index's whole lookup cost.
#[derive(Default)]
pub struct ClusterRadixIndex {
    sets: FxHashMap<usize, FxHashSet<u128>>,
}

impl ClusterRadixIndex {
    /// Replace a replica's summary (called on digest sync).
    pub fn update(&mut self, replica: usize, keys: &[u128]) {
        self.sets.insert(replica, keys.iter().copied().collect());
    }

    /// Apply a delta summary: drop `removed`, then add `added`. The sets
    /// are disjoint (the replica cancels within-window churn), so order
    /// only matters for defensiveness.
    pub fn apply_delta(&mut self, replica: usize, added: &[u128], removed: &[u128]) {
        let set = self.sets.entry(replica).or_default();
        for k in removed {
            set.remove(k);
        }
        set.extend(added.iter().copied());
    }

    /// Optimistically add keys a replica is about to cache (dispatch-time
    /// update, so same-group arrivals within one sync quantum co-locate).
    pub fn extend(&mut self, replica: usize, keys: &[u128]) {
        self.sets.entry(replica).or_default().extend(keys.iter().copied());
    }

    /// Like `extend`, but returns the keys that were actually new — the
    /// router records those as speculative and retracts them at the next
    /// sync (a truly-cached key reappears in the replica's own summary,
    /// full or delta; an uncached one must not linger).
    fn extend_tracked(&mut self, replica: usize, keys: &[u128]) -> Vec<u128> {
        let set = self.sets.entry(replica).or_default();
        keys.iter().copied().filter(|&k| set.insert(k)).collect()
    }

    fn retract(&mut self, replica: usize, keys: &[u128]) {
        if let Some(set) = self.sets.get_mut(&replica) {
            for k in keys {
                set.remove(k);
            }
        }
    }

    pub fn remove(&mut self, replica: usize) {
        self.sets.remove(&replica);
    }

    /// Radix descent: leading keys of `keys` the replica holds.
    pub fn cached_depth(&self, replica: usize, keys: &[u128]) -> usize {
        match self.sets.get(&replica) {
            Some(set) => keys.iter().take_while(|k| set.contains(k)).count(),
            None => 0,
        }
    }

    pub fn total_keys(&self) -> usize {
        self.sets.values().map(|s| s.len()).sum()
    }

    /// Sorted key set the index holds for one replica (test introspection:
    /// the delta-vs-full equivalence property compares these directly).
    #[doc(hidden)]
    pub fn replica_key_set(&self, replica: usize) -> Vec<u128> {
        let mut v: Vec<u128> = self
            .sets
            .get(&replica)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default();
        v.sort_unstable();
        v
    }
}

/// Router decision counters (cluster report).
#[derive(Clone, Debug, Default)]
pub struct RouterStats {
    pub dispatched_online: usize,
    /// Dispatches won by a warm prefix (depth > 0).
    pub affinity_routed: usize,
    /// Tokens the affinity target already held at dispatch time.
    pub predicted_hit_tokens: u64,
    /// A warm replica lost a dispatch because its KV headroom was short.
    pub capacity_vetoes: usize,
    /// No replica had headroom; least-loaded took the overflow.
    pub overflow_dispatches: usize,
}

pub struct Router {
    pub index: ClusterRadixIndex,
    /// Last synced digest per replica. BTreeMap: deterministic iteration
    /// (dispatch decisions must reproduce across runs).
    digests: BTreeMap<usize, LoadDigest>,
    /// Keys speculatively added per replica at dispatch time since its
    /// last sync; retracted when the replica's own summary arrives (under
    /// the delta protocol nothing else would ever clean up a speculation
    /// the replica did not actually cache).
    optimistic: FxHashMap<usize, Vec<u128>>,
    time_model: TimeModel,
    block_size: usize,
    pub stats: RouterStats,
}

impl Router {
    pub fn new(time_model: TimeModel, block_size: usize) -> Self {
        Router {
            index: ClusterRadixIndex::default(),
            digests: BTreeMap::new(),
            optimistic: FxHashMap::default(),
            time_model,
            block_size,
            stats: RouterStats::default(),
        }
    }

    /// Absorb a freshly published digest: retract this replica's dispatch
    /// speculations (its own summary is the truth — anything it really
    /// cached comes back as `Full` content or `Delta::added`), then apply
    /// the summary.
    pub fn sync(&mut self, d: LoadDigest) {
        if let Some(spec) = self.optimistic.remove(&d.replica) {
            self.index.retract(d.replica, &spec);
        }
        match &d.summary {
            PrefixSummary::Full(keys) => self.index.update(d.replica, keys),
            PrefixSummary::Delta { added, removed } => {
                self.index.apply_delta(d.replica, added, removed)
            }
        }
        self.digests.insert(d.replica, d);
    }

    /// Drop a retired replica.
    pub fn forget(&mut self, replica: usize) {
        self.index.remove(replica);
        self.digests.remove(&replica);
        self.optimistic.remove(&replica);
    }

    pub fn digest(&self, replica: usize) -> Option<&LoadDigest> {
        self.digests.get(&replica)
    }

    pub fn known_replicas(&self) -> impl Iterator<Item = usize> + '_ {
        self.digests.keys().copied()
    }

    /// Estimator-predicted latency for a new arrival on this replica:
    /// its own fresh prefill (Eq. 6, chunk-extended) queued behind the
    /// replica's pending prefill work, plus an iteration tax per running
    /// request (each decode round the arrival must share).
    pub fn predicted_latency(&self, d: &LoadDigest, fresh_tokens: usize, context: usize) -> f64 {
        let own = self.time_model.prefill_item(PrefillItem {
            chunk: fresh_tokens.max(1),
            context,
        });
        let queued = if d.pending_prefill_tokens > 0 {
            self.time_model.prefill_item(PrefillItem {
                chunk: d.pending_prefill_tokens,
                context: 0,
            })
        } else {
            0.0
        };
        let decode_tax = (d.running_online + d.running_offline) as f64 * self.time_model.cfg.c;
        own + queued + decode_tax
    }

    /// Optimistic digest update so a burst within one sync quantum spreads
    /// instead of piling onto a single stale-looking replica; the index
    /// extension co-locates same-group arrivals.
    fn note_dispatch(
        &mut self,
        replica: usize,
        prompt_len: usize,
        hit_tokens: usize,
        fresh: usize,
        keys: &[u128],
    ) {
        self.stats.dispatched_online += 1;
        if let Some(d) = self.digests.get_mut(&replica) {
            d.queued_online += 1;
            d.pending_prefill_tokens += prompt_len - hit_tokens;
            d.free_blocks = d.free_blocks.saturating_sub(fresh);
        }
        let speculated = self.index.extend_tracked(replica, keys);
        if !speculated.is_empty() {
            self.optimistic.entry(replica).or_default().extend(speculated);
        }
    }

    /// Affinity/latency score of one replica for one arrival:
    /// `(depth, hit_tokens, fresh_blocks, predicted_latency)`.
    fn score(
        &self,
        d: &LoadDigest,
        keys: &[u128],
        total_blocks: usize,
        prompt_len: usize,
    ) -> (usize, usize, usize, f64) {
        let depth = self.index.cached_depth(d.replica, keys).min(total_blocks);
        let hit_tokens = (depth * self.block_size).min(prompt_len.saturating_sub(1));
        let fresh = total_blocks - depth;
        let predicted = self.predicted_latency(d, prompt_len - hit_tokens, hit_tokens);
        (depth, hit_tokens, fresh, predicted)
    }

    /// Route one online arrival; returns `(replica, predicted_hit_tokens)`.
    /// `None` only when the router knows no replica at all.
    pub fn route_online(&mut self, prompt: &PromptSpec) -> Option<(usize, usize)> {
        let keys = prompt.affinity_keys(self.block_size);
        let total_blocks = (prompt.total_len + 1).div_ceil(self.block_size);

        // (depth, hit_tokens, fresh_blocks, predicted, replica)
        let mut best_feasible: Option<(usize, usize, usize, f64, usize)> = None;
        let mut best_any: Option<(f64, usize, usize)> = None; // (predicted, replica, fresh)
        let mut deepest_vetoed = 0usize;
        let mut candidates = 0usize;
        for d in self.digests.values().filter(|d| !d.draining && !d.degraded) {
            candidates += 1;
            let (depth, hit_tokens, fresh, predicted) =
                self.score(d, &keys, total_blocks, prompt.total_len);
            if fresh <= d.free_blocks {
                let better = match &best_feasible {
                    None => true,
                    Some(&(bd, _, _, bp, _)) => {
                        depth > bd || (depth == bd && predicted < bp)
                    }
                };
                if better {
                    best_feasible = Some((depth, hit_tokens, fresh, predicted, d.replica));
                }
            } else {
                deepest_vetoed = deepest_vetoed.max(depth);
            }
            if best_any.map_or(true, |(bp, _, _)| predicted < bp) {
                best_any = Some((predicted, d.replica, fresh));
            }
        }
        if candidates == 0 {
            // Only draining/degraded replicas remain (a scale-down or
            // quarantine transient, not a capacity problem): dispatch to
            // the least-predicted-latency one without charging
            // overflow/veto stats. Degraded replicas pay a latency
            // penalty so a healthy draining replica still wins.
            let mut fallback: Option<(f64, usize, usize, usize)> = None;
            for d in self.digests.values() {
                let (_, hit, fresh, mut predicted) =
                    self.score(d, &keys, total_blocks, prompt.total_len);
                if d.degraded {
                    predicted *= DEGRADED_PENALTY;
                }
                if fallback.map_or(true, |(bp, _, _, _)| predicted < bp) {
                    fallback = Some((predicted, d.replica, hit, fresh));
                }
            }
            let (_, replica, hit_tokens, fresh) = fallback?;
            self.note_dispatch(replica, prompt.total_len, hit_tokens, fresh, &keys);
            return Some((replica, hit_tokens));
        }

        let (replica, hit_tokens, fresh) = match best_feasible {
            Some((depth, hit_tokens, fresh, _, replica)) => {
                if depth > 0 {
                    self.stats.affinity_routed += 1;
                    self.stats.predicted_hit_tokens += hit_tokens as u64;
                }
                if deepest_vetoed > depth {
                    self.stats.capacity_vetoes += 1;
                }
                (replica, hit_tokens, fresh)
            }
            None => {
                let (_, replica, fresh) = best_any?;
                self.stats.overflow_dispatches += 1;
                if deepest_vetoed > 0 {
                    self.stats.capacity_vetoes += 1;
                }
                (replica, 0, fresh)
            }
        };
        self.note_dispatch(replica, prompt.total_len, hit_tokens, fresh, &keys);
        Some((replica, hit_tokens))
    }

    /// Live (non-draining, non-degraded) replicas ordered for offline
    /// work-stealing: emptiest pool first, then fewest running/queued,
    /// then id. Degraded replicas are skipped — feeding a sick replica
    /// stolen work would just strand it there again.
    pub fn steal_order(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .digests
            .values()
            .filter(|d| !d.draining && !d.degraded)
            .map(|d| d.replica)
            .collect();
        ids.sort_by_key(|r| {
            let d = &self.digests[r];
            (
                d.pool_backlog,
                d.running_offline + d.running_online + d.queued_online,
                *r,
            )
        });
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn digest(replica: usize, free_blocks: usize) -> LoadDigest {
        LoadDigest {
            replica,
            clock: 0.0,
            queued_online: 0,
            running_online: 0,
            running_offline: 0,
            pool_backlog: 0,
            pending_prefill_tokens: 0,
            free_blocks,
            block_size: 16,
            draining: false,
            degraded: false,
            summary: PrefixSummary::Full(Vec::new()),
        }
    }

    fn router() -> Router {
        let cfg = SystemConfig::a100_llama8b();
        Router::new(TimeModel::new(cfg.time_model), cfg.cache.block_size)
    }

    fn shared_prompt(group: u64, len: usize, shared_len: usize) -> PromptSpec {
        PromptSpec::sim(len, Some((group, shared_len)))
    }

    #[test]
    fn affinity_keys_exclude_private_tail() {
        let p = shared_prompt(7, 320, 160);
        let keys = affinity_keys(&p, 16);
        assert_eq!(keys.len(), 10, "160 shared tokens = 10 shareable blocks");
        let q = PromptSpec::sim(320, None);
        assert!(affinity_keys(&q, 16).is_empty(), "no sharing, no affinity");
    }

    #[test]
    fn warm_replica_wins() {
        let mut r = router();
        let p = shared_prompt(9, 480, 320);
        let keys = affinity_keys(&p, 16);
        let mut d0 = digest(0, 10_000);
        d0.summary = PrefixSummary::Full(keys[..8].to_vec());
        r.sync(d0);
        r.sync(digest(1, 10_000));
        let (replica, hit) = r.route_online(&p).unwrap();
        assert_eq!(replica, 0);
        assert_eq!(hit, 8 * 16);
        assert_eq!(r.stats.affinity_routed, 1);
    }

    #[test]
    fn capacity_vetoes_warm_replica() {
        let mut r = router();
        let p = shared_prompt(9, 480, 320);
        let keys = affinity_keys(&p, 16);
        // Warm but nearly out of memory: 480+1 tokens need 31 blocks,
        // 20 cached leaves 11 fresh > 4 free.
        let mut d0 = digest(0, 4);
        d0.summary = PrefixSummary::Full(keys.clone());
        r.sync(d0);
        r.sync(digest(1, 10_000));
        let (replica, _) = r.route_online(&p).unwrap();
        assert_eq!(replica, 1, "warm replica must be vetoed on capacity");
        assert_eq!(r.stats.capacity_vetoes, 1);
    }

    #[test]
    fn cold_ties_break_on_predicted_latency() {
        let mut r = router();
        let mut d0 = digest(0, 10_000);
        d0.pending_prefill_tokens = 50_000; // long queue
        d0.running_online = 30;
        r.sync(d0);
        r.sync(digest(1, 10_000));
        let p = PromptSpec::sim(300, None);
        let (replica, hit) = r.route_online(&p).unwrap();
        assert_eq!(replica, 1, "idle replica must win the cold tie");
        assert_eq!(hit, 0);
    }

    #[test]
    fn overflow_goes_to_least_loaded() {
        let mut r = router();
        r.sync(digest(0, 0));
        let mut d1 = digest(1, 0);
        d1.pending_prefill_tokens = 9_999;
        r.sync(d1);
        let p = PromptSpec::sim(300, None);
        let (replica, _) = r.route_online(&p).unwrap();
        assert_eq!(replica, 0);
        assert_eq!(r.stats.overflow_dispatches, 1);
    }

    #[test]
    fn optimistic_updates_spread_bursts() {
        let mut r = router();
        r.sync(digest(0, 10_000));
        r.sync(digest(1, 10_000));
        let p = PromptSpec::sim(300, None);
        let (first, _) = r.route_online(&p).unwrap();
        let (second, _) = r.route_online(&p).unwrap();
        assert_ne!(first, second, "second arrival must see the first's load");
        assert_eq!(r.stats.dispatched_online, 2);
    }

    #[test]
    fn draining_excluded_until_last_resort() {
        let mut r = router();
        let mut d0 = digest(0, 10_000);
        d0.draining = true;
        r.sync(d0);
        r.sync(digest(1, 10_000));
        let p = PromptSpec::sim(100, None);
        assert_eq!(r.route_online(&p).unwrap().0, 1);
        // Only draining replicas left: still dispatches (exactly once).
        r.forget(1);
        assert_eq!(r.route_online(&p).unwrap().0, 0);
    }

    #[test]
    fn degraded_routed_around_and_penalized_last_resort() {
        let mut r = router();
        let mut d0 = digest(0, 10_000);
        d0.degraded = true;
        r.sync(d0);
        r.sync(digest(1, 10_000));
        let p = PromptSpec::sim(100, None);
        // Healthy replica wins even though the degraded one looks idle.
        assert_eq!(r.route_online(&p).unwrap().0, 1);
        assert_eq!(r.steal_order(), vec![1], "stealing skips degraded");
        // Only a degraded replica and a loaded *draining* one remain: the
        // penalty keeps the healthy draining replica preferred.
        r.forget(1);
        let mut d2 = digest(2, 10_000);
        d2.draining = true;
        d2.running_online = 2;
        r.sync(d2);
        assert_eq!(r.route_online(&p).unwrap().0, 2);
        assert!(r.steal_order().is_empty());
    }

    #[test]
    fn steal_order_prefers_empty_pools() {
        let mut r = router();
        let mut d0 = digest(0, 100);
        d0.pool_backlog = 50;
        r.sync(d0);
        r.sync(digest(1, 100));
        let mut d2 = digest(2, 100);
        d2.draining = true;
        r.sync(d2);
        assert_eq!(r.steal_order(), vec![1, 0]);
    }

    #[test]
    fn delta_sync_matches_full_resync() {
        let p = shared_prompt(5, 640, 640);
        let keys = affinity_keys(&p, 16);
        let mut r = router();
        let mut d0 = digest(0, 10_000);
        d0.summary = PrefixSummary::Full(keys[..10].to_vec());
        r.sync(d0);
        assert_eq!(r.index.cached_depth(0, &keys), 10);
        // Delta: drop the deepest 4, add 2 more past the old horizon.
        let mut d1 = digest(0, 10_000);
        d1.summary = PrefixSummary::Delta {
            added: keys[10..12].to_vec(),
            removed: keys[6..10].to_vec(),
        };
        r.sync(d1);
        // Walk stops at the first missing key (depth 6), like a full
        // resync with the equivalent key set would.
        assert_eq!(r.index.cached_depth(0, &keys), 6);
        let mut rf = router();
        let mut df = digest(0, 10_000);
        let mut set: Vec<u128> = keys[..6].to_vec();
        set.extend_from_slice(&keys[10..12]);
        df.summary = PrefixSummary::Full(set);
        rf.sync(df);
        assert_eq!(rf.index.cached_depth(0, &keys), r.index.cached_depth(0, &keys));
    }

    #[test]
    fn dispatch_speculation_retracted_on_sync() {
        let mut r = router();
        r.sync(digest(0, 10_000));
        let p = shared_prompt(6, 480, 480);
        let keys = affinity_keys(&p, 16);
        let (replica, _) = r.route_online(&p).unwrap();
        assert_eq!(replica, 0);
        assert!(
            r.index.cached_depth(0, &keys) > 0,
            "dispatch must speculate the keys"
        );
        // The replica's next digest is an *empty* delta (it cached nothing):
        // the speculation must not linger.
        let mut d = digest(0, 10_000);
        d.summary = PrefixSummary::Delta {
            added: vec![],
            removed: vec![],
        };
        r.sync(d);
        assert_eq!(
            r.index.cached_depth(0, &keys),
            0,
            "unconfirmed speculation must be retracted at sync"
        );
    }

    #[test]
    fn radix_index_walks_chain_prefix() {
        let mut idx = ClusterRadixIndex::default();
        let p = shared_prompt(3, 640, 640);
        let keys = affinity_keys(&p, 16);
        idx.update(0, &keys[..5]);
        assert_eq!(idx.cached_depth(0, &keys), 5);
        assert_eq!(idx.cached_depth(1, &keys), 0);
        // A different group shares no keys (chain hashes commit to prefix).
        let q = shared_prompt(4, 640, 640);
        assert_eq!(idx.cached_depth(0, &affinity_keys(&q, 16)), 0);
        idx.extend(0, &keys);
        assert_eq!(idx.cached_depth(0, &keys), keys.len());
        idx.remove(0);
        assert_eq!(idx.cached_depth(0, &keys), 0);
    }
}
